//! Compressed N:M storage + sparse matmul (Sparse-Tensor-Core analogue).

use super::{NmConfig, NmMask};
use crate::tensor::Mat;
use crate::util::pool::parallel_map;

/// An N:M-sparse weight in compressed form: retained values plus column
/// metadata, `K = C_in / m * keep` entries per output row.
///
/// For 2:4 this halves both storage and the length of every inner product
/// — the mechanism behind the paper's Table 3 speedups. Layout matches
/// `ref.nm_compress_ref` / the `nm_spmm` Pallas kernel: within each group
/// retained entries appear in ascending column order.
///
/// Metadata is stored as one `u8` *within-group column offset* per
/// retained entry (the analogue of NVIDIA's 2-bit sparse-tensor-core
/// metadata; groups are at most M wide, so a byte always suffices).  The
/// group of entry `e` in a row is implicit — `e / keep` — so the absolute
/// column is `(e / keep) * m + offset`.
#[derive(Debug, Clone)]
pub struct Compressed {
    cfg: NmConfig,
    c_out: usize,
    c_in: usize,
    /// `[C_out, K]` retained values, row-major.
    vals: Vec<f32>,
    /// `[C_out, K]` within-group column offsets (`0..m`), row-major.
    meta: Vec<u8>,
    /// `[C_out, K]` absolute column indices, memoized at build time —
    /// redundant with `meta` (`idx[e] = (e % K / keep) * m + meta[e]`) but
    /// precomputed once so the matmul inner loop is a pure gather and
    /// [`Compressed::idx`] never allocates.
    idx: Vec<u32>,
}

impl Compressed {
    /// Compress `mask ⊙ w`.
    pub fn compress(w: &Mat, mask: &NmMask) -> Compressed {
        let (c_out, c_in) = w.shape();
        assert_eq!(mask.shape(), (c_out, c_in));
        let cfg = mask.cfg();
        assert!(cfg.m <= 256, "group width {} does not fit u8 metadata", cfg.m);
        let k = c_in / cfg.m * cfg.keep;
        let mut vals = Vec::with_capacity(c_out * k);
        let mut meta = Vec::with_capacity(c_out * k);
        let mut idx = Vec::with_capacity(c_out * k);
        for r in 0..c_out {
            let row = w.row(r);
            for c in 0..c_in {
                if mask.get(r, c) {
                    vals.push(row[c]);
                    meta.push((c % cfg.m) as u8);
                    idx.push(c as u32);
                }
            }
            debug_assert_eq!(vals.len(), (r + 1) * k, "mask not N:M at row {r}");
        }
        Compressed { cfg, c_out, c_in, vals, meta, idx }
    }

    /// Rebuild compressed storage from raw buffers (the `sparse_fwd`
    /// artifact's input layout, with absolute column indices).
    ///
    /// Validates the full group structure, not just counts and bounds:
    /// entry `e` of a row must land in group `e / keep` (which forces
    /// exactly `keep` retained columns per M-wide group) and indices must
    /// be strictly ascending within each group — the invariants
    /// [`Compressed::to_dense`] and [`Compressed::matmul_xt`] rely on.
    /// Duplicate, out-of-group, or descending indices are rejected with an
    /// error naming the offending row/entry.
    pub fn from_parts(
        cfg: NmConfig,
        c_out: usize,
        c_in: usize,
        vals: Vec<f32>,
        idx: Vec<u32>,
    ) -> anyhow::Result<Compressed> {
        anyhow::ensure!(cfg.m > 0 && cfg.keep <= cfg.m, "bad N:M config {cfg:?}");
        anyhow::ensure!(cfg.m <= 256, "group width {} does not fit u8 metadata", cfg.m);
        anyhow::ensure!(c_in % cfg.m == 0, "C_in {c_in} not divisible by M {}", cfg.m);
        let k = c_in / cfg.m * cfg.keep;
        anyhow::ensure!(
            vals.len() == c_out * k && idx.len() == c_out * k,
            "vals/idx have {}/{} entries, expected {}",
            vals.len(),
            idx.len(),
            c_out * k
        );
        let mut meta = Vec::with_capacity(idx.len());
        for r in 0..c_out {
            for e in 0..k {
                let c = idx[r * k + e] as usize;
                anyhow::ensure!(
                    c < c_in,
                    "row {r} entry {e}: column index {c} out of range (C_in {c_in})"
                );
                let group = e / cfg.keep.max(1);
                anyhow::ensure!(
                    c / cfg.m == group,
                    "row {r} entry {e}: column {c} belongs to group {}, expected group {group} \
                     (every M-wide group must retain exactly keep={} columns)",
                    c / cfg.m,
                    cfg.keep
                );
                if e % cfg.keep.max(1) > 0 {
                    let prev = idx[r * k + e - 1] as usize;
                    anyhow::ensure!(
                        c > prev,
                        "row {r} entry {e}: column {c} not strictly ascending after {prev} \
                         within group {group}"
                    );
                }
                meta.push((c % cfg.m) as u8);
            }
        }
        Ok(Compressed { cfg, c_out, c_in, vals, meta, idx })
    }

    pub fn cfg(&self) -> NmConfig {
        self.cfg
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.c_out, self.c_in)
    }

    /// Entries per output row.
    pub fn k(&self) -> usize {
        self.c_in / self.cfg.m * self.cfg.keep
    }

    /// Compressed values `[C_out, K]` (for feeding the sparse_fwd artifact).
    pub fn vals(&self) -> &[f32] {
        &self.vals
    }

    /// Raw `[C_out, K]` within-group column offsets (the stored metadata).
    pub fn meta(&self) -> &[u8] {
        &self.meta
    }

    /// Column metadata `[C_out, K]` as absolute column indices (the
    /// `sparse_fwd` artifact's input layout).  Memoized at build time —
    /// repeated calls (every `NativeEngine` bind, the PJRT literal
    /// conversion, snapshot dumps) borrow the same table instead of
    /// reconstructing a fresh `Vec` from the per-group offsets.
    pub fn idx(&self) -> &[u32] {
        &self.idx
    }

    /// Bytes of storage: f32 values plus one metadata byte per entry (the
    /// per-group u8 offsets actually stored — the paper's 2-bit NVIDIA
    /// metadata rounded up to a byte).  The memoized absolute-index table
    /// is derived acceleration structure, not storage format, so it is
    /// deliberately not counted.
    pub fn storage_bytes(&self) -> usize {
        self.vals.len() * 4 + self.meta.len()
    }

    /// Decompress to a dense matrix (zeros at pruned positions).
    pub fn to_dense(&self) -> Mat {
        let k = self.k();
        let (m, keep) = (self.cfg.m, self.cfg.keep.max(1));
        let mut out = Mat::zeros(self.c_out, self.c_in);
        for r in 0..self.c_out {
            for e in 0..k {
                let c = (e / keep) * m + self.meta[r * k + e] as usize;
                out[(r, c)] = self.vals[r * k + e];
            }
        }
        out
    }

    /// Sparse matmul: `y = x W_sparse^T` for activations `x: [T, C_in]`.
    ///
    /// Each output element is a K-length gather-dot instead of a C_in-length
    /// dense dot — exactly the 2x work reduction of 2:4 sparsity.
    ///
    /// Sequential entry point; equals [`Compressed::matmul_xt_threads`]
    /// with one worker bit-for-bit.
    pub fn matmul_xt(&self, x: &Mat) -> Mat {
        self.matmul_xt_threads(x, 1)
    }

    /// [`Compressed::matmul_xt`] parallelized across output-row tiles of
    /// the compressed weight: each worker computes a contiguous band of
    /// output channels over the whole activation batch, so a single
    /// request's latency shrinks with cores (not just batch throughput).
    ///
    /// Every output element runs the identical per-group accumulation
    /// regardless of the tile split, so the result is bit-identical to the
    /// sequential path for any `threads` (pinned by
    /// `parallel_matmul_is_bit_identical`).
    pub fn matmul_xt_threads(&self, x: &Mat, threads: usize) -> Mat {
        // INVARIANT: matmul_xt_threads_into writes every element of the
        // output — each (row, output-channel) pair is computed and stored
        // exactly once — so the zero-fill of Mat::zeros would be dead
        // stores.
        let mut out = Mat::uninit_filled(x.rows(), self.c_out);
        self.matmul_xt_threads_into(x, threads, &mut out);
        out
    }

    /// [`Compressed::matmul_xt_threads`] writing into an existing
    /// `[T, C_out]` matrix — the zero-allocation form the arena-backed
    /// serving hot path uses (`out` is recycled scratch).  Every element
    /// of `out` is overwritten.
    pub fn matmul_xt_threads_into(&self, x: &Mat, threads: usize, out: &mut Mat) {
        assert_eq!(x.cols(), self.c_in);
        let t = x.rows();
        assert_eq!(out.shape(), (t, self.c_out), "matmul output shape mismatch");
        let n_tiles = threads.max(1).min(self.c_out.max(1));
        if n_tiles <= 1 {
            self.matmul_range_into(x, 0, self.c_out, out);
            return;
        }
        let per = self.c_out.div_ceil(n_tiles);
        let tiles = parallel_map(n_tiles, n_tiles, |ti| {
            let o0 = (ti * per).min(self.c_out);
            let o1 = ((ti + 1) * per).min(self.c_out);
            // Fully overwritten by matmul_range_into before any read.
            let mut band = Mat::uninit_filled(t, o1 - o0);
            self.matmul_range_into(x, o0, o1, &mut band);
            (o0, band)
        });
        for (o0, tile) in tiles {
            let width = tile.cols();
            for r in 0..t {
                out.row_mut(r)[o0..o0 + width].copy_from_slice(tile.row(r));
            }
        }
    }

    /// The kernel for output channels `[o0, o1)`, writing the `[T, o1-o0]`
    /// band `out` (every element overwritten).
    ///
    /// Loop order is output-row-major (§Perf iteration 1): the compressed
    /// row (vals + idx, ~1.5 KB) is loaded once and streamed against a
    /// tile of activation rows, instead of re-streaming the whole
    /// compressed matrix (hundreds of KB) per activation row.  Within a
    /// tile the T axis is processed in fixed-width blocks of `LANES`
    /// rows (§Perf iteration 3): each compressed entry is gathered once
    /// and multiplied against `LANES` activation rows with per-lane
    /// accumulators — contiguous `[f32; LANES]` arithmetic the
    /// autovectorizer turns into SIMD lanes.  The absolute column of each
    /// entry comes from the precomputed `idx` table, so the hot loop is a
    /// pure gather-FMA with no `(e/keep)*m + meta[e]` address arithmetic.
    ///
    /// Bit-identity with [`Compressed::matmul_xt_scalar`] holds by
    /// construction: every output element accumulates the same `keep`-wide
    /// group partials in the same order whether it sits in a lane block or
    /// the scalar remainder — only *which other elements* are computed
    /// alongside it changes.
    fn matmul_range_into(&self, x: &Mat, o0: usize, o1: usize, out: &mut Mat) {
        let t = x.rows();
        let k = self.k();
        let keep = self.cfg.keep.max(1);
        let width = o1 - o0;
        let ocols = out.cols();
        debug_assert_eq!(out.rows(), t);
        debug_assert!(width <= ocols);
        let c_in = self.c_in;
        let xd = x.data();
        let od = out.data_mut();
        /// Activation rows per vector block: wide enough to fill two
        /// 4-lane SSE / one 8-lane AVX register file of accumulators.
        const LANES: usize = 8;
        /// Activation rows per L2 tile (a multiple of `LANES`, so full
        /// tiles split into whole lane blocks).
        const T_TILE: usize = 64;
        for t0 in (0..t).step_by(T_TILE) {
            let t1 = (t0 + T_TILE).min(t);
            for o in o0..o1 {
                let vals = &self.vals[o * k..(o + 1) * k];
                let idx = &self.idx[o * k..(o + 1) * k];
                let col = o - o0;
                let mut tb = t0;
                while tb + LANES <= t1 {
                    let mut acc = [0.0f32; LANES];
                    let mut e = 0;
                    while e < k {
                        let mut group_acc = [0.0f32; LANES];
                        for j in 0..keep {
                            let w = vals[e + j];
                            let c = idx[e + j] as usize;
                            for (l, g) in group_acc.iter_mut().enumerate() {
                                *g += w * xd[(tb + l) * c_in + c];
                            }
                        }
                        for (a, g) in acc.iter_mut().zip(group_acc) {
                            *a += g;
                        }
                        e += keep;
                    }
                    for (l, a) in acc.into_iter().enumerate() {
                        od[(tb + l) * ocols + col] = a;
                    }
                    tb += LANES;
                }
                // Scalar remainder: t % LANES rows, same per-element
                // accumulation order as the lane blocks.
                for ti in tb..t1 {
                    let xrow = &xd[ti * c_in..(ti + 1) * c_in];
                    let mut acc = 0.0f32;
                    let mut e = 0;
                    while e < k {
                        let mut group_acc = 0.0f32;
                        for j in 0..keep {
                            group_acc += vals[e + j] * xrow[idx[e + j] as usize];
                        }
                        acc += group_acc;
                        e += keep;
                    }
                    od[ti * ocols + col] = acc;
                }
            }
        }
    }

    /// The pre-vectorization scalar kernel, kept verbatim as the
    /// reference the property tests and the bench's
    /// `kernel_speedup_vs_scalar` ratio compare against: one activation
    /// row at a time, absolute columns recomputed from the per-group
    /// offsets in the inner loop.  Bit-identical to
    /// [`Compressed::matmul_xt`] (same per-element accumulation order).
    pub fn matmul_xt_scalar(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols(), self.c_in);
        let t = x.rows();
        let k = self.k();
        let (m, keep) = (self.cfg.m, self.cfg.keep.max(1));
        let mut out = Mat::zeros(t, self.c_out);
        const T_TILE: usize = 64;
        for t0 in (0..t).step_by(T_TILE) {
            let t1 = (t0 + T_TILE).min(t);
            for o in 0..self.c_out {
                let vals = &self.vals[o * k..(o + 1) * k];
                let meta = &self.meta[o * k..(o + 1) * k];
                for ti in t0..t1 {
                    let xrow = x.row(ti);
                    let mut acc = 0.0f32;
                    let mut e = 0;
                    let mut base = 0;
                    while e < k {
                        let mut group_acc = 0.0f32;
                        for j in 0..keep {
                            group_acc += vals[e + j] * xrow[base + meta[e + j] as usize];
                        }
                        acc += group_acc;
                        e += keep;
                        base += m;
                    }
                    out.data_mut()[ti * self.c_out + o] = acc;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    fn sample(rng: &mut Pcg32, c_out: usize, c_in: usize, cfg: NmConfig) -> (Mat, NmMask) {
        let w = Mat::randn(c_out, c_in, 1.0, rng);
        let m = NmMask::from_scores(&w.map(f32::abs), cfg);
        (w, m)
    }

    #[test]
    fn prop_compress_roundtrips_to_masked_dense() {
        testkit::check("compress-roundtrip", |rng| {
            for cfg in [NmConfig::PAT_2_4, NmConfig::PAT_4_8] {
                let c_out = 1 + rng.below_usize(6);
                let c_in = cfg.m * (1 + rng.below_usize(6));
                let (w, m) = sample(rng, c_out, c_in, cfg);
                let comp = Compressed::compress(&w, &m);
                let dense = comp.to_dense();
                let want = m.apply(&w);
                testkit::assert_close(dense.data(), want.data(), 1e-7)?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sparse_matmul_matches_masked_dense_matmul() {
        testkit::check("spmm-vs-dense", |rng| {
            let cfg = NmConfig::PAT_2_4;
            let c_out = 2 + rng.below_usize(6);
            let c_in = cfg.m * (2 + rng.below_usize(6));
            let t = 1 + rng.below_usize(5);
            let (w, m) = sample(rng, c_out, c_in, cfg);
            let x = Mat::randn(t, c_in, 1.0, rng);
            let comp = Compressed::compress(&w, &m);
            let got = comp.matmul_xt(&x);
            let want = x.matmul_bt(&m.apply(&w));
            testkit::assert_close(got.data(), want.data(), 1e-5)
        });
    }

    #[test]
    fn prop_parallel_matmul_is_bit_identical() {
        testkit::check("spmm-parallel-determinism", |rng| {
            let cfg = if rng.below(2) == 0 { NmConfig::PAT_2_4 } else { NmConfig::PAT_4_8 };
            let c_out = 1 + rng.below_usize(12);
            let c_in = cfg.m * (1 + rng.below_usize(6));
            // Straddle the LANES=8 block width so both the lane-blocked
            // body and the scalar remainder are exercised.
            let t = 1 + rng.below_usize(20);
            let (w, m) = sample(rng, c_out, c_in, cfg);
            let x = Mat::randn(t, c_in, 1.0, rng);
            let comp = Compressed::compress(&w, &m);
            // The pre-vectorization scalar kernel is the root reference:
            // the lane-blocked sequential path must reproduce it
            // bit-for-bit (same per-group accumulation order), and every
            // thread count must reproduce the sequential path.
            let scalar = comp.matmul_xt_scalar(&x);
            let seq = comp.matmul_xt(&x);
            if seq.data() != scalar.data() {
                return Err(format!(
                    "vectorized kernel diverged from scalar ({c_out}x{c_in}, t={t})"
                ));
            }
            for threads in [2usize, 3, 8, 64] {
                let par = comp.matmul_xt_threads(&x, threads);
                if par.data() != seq.data() {
                    return Err(format!(
                        "threads={threads} diverged from sequential ({c_out}x{c_in}, t={t})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn vectorized_kernel_bit_identical_on_awkward_shapes() {
        // Deterministic sweep over shapes chosen to hit every edge of the
        // lane blocking: T below / at / just past / far past LANES=8 and
        // the T_TILE=64 boundary, with non-multiple c_out, at both
        // sparsity patterns and several thread counts.
        let mut rng = Pcg32::seeded(41);
        for cfg in [NmConfig::PAT_2_4, NmConfig::PAT_4_8] {
            for (t, c_out) in [(1, 3), (7, 5), (8, 1), (9, 13), (17, 7), (64, 3), (65, 11)] {
                let c_in = cfg.m * 4;
                let (w, m) = sample(&mut rng, c_out, c_in, cfg);
                let comp = Compressed::compress(&w, &m);
                let x = Mat::randn(t, c_in, 1.0, &mut rng);
                let scalar = comp.matmul_xt_scalar(&x);
                for threads in [1usize, 2, 5] {
                    let got = comp.matmul_xt_threads(&x, threads);
                    assert_eq!(
                        got.data(),
                        scalar.data(),
                        "t={t} c_out={c_out} threads={threads} m={}",
                        cfg.m
                    );
                }
            }
        }
    }

    #[test]
    fn storage_is_half_plus_metadata_for_2_4() {
        let mut rng = Pcg32::seeded(1);
        let (w, m) = sample(&mut rng, 8, 64, NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &m);
        let dense_bytes = 8 * 64 * 4;
        assert_eq!(comp.vals().len(), 8 * 32);
        assert_eq!(comp.meta().len(), 8 * 32);
        // values: exactly half the dense bytes; metadata is genuinely one
        // u8 per-group offset per entry => 0.625x dense total, exactly.
        assert_eq!(comp.storage_bytes(), 8 * 32 * 4 + 8 * 32);
        assert!(comp.storage_bytes() <= dense_bytes * 65 / 100);
    }

    #[test]
    fn from_parts_roundtrips_and_validates() {
        let mut rng = Pcg32::seeded(3);
        let (w, m) = sample(&mut rng, 4, 16, NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &m);
        let back = Compressed::from_parts(
            comp.cfg(),
            4,
            16,
            comp.vals().to_vec(),
            comp.idx().to_vec(),
        )
        .unwrap();
        assert_eq!(back.to_dense().data(), comp.to_dense().data());
        // Wrong entry count and out-of-range indices are rejected.
        assert!(Compressed::from_parts(comp.cfg(), 4, 16, vec![0.0; 3], vec![0; 3]).is_err());
        let mut bad_idx = comp.idx().to_vec();
        bad_idx[0] = 999;
        assert!(
            Compressed::from_parts(comp.cfg(), 4, 16, comp.vals().to_vec(), bad_idx).is_err()
        );
    }

    #[test]
    fn from_parts_rejects_corrupt_group_structure() {
        let mut rng = Pcg32::seeded(6);
        let (w, m) = sample(&mut rng, 2, 8, NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &m);
        let good = comp.idx().to_vec();

        // Duplicate column within a group (in-bounds, right group).
        let mut dup = good.clone();
        dup[1] = dup[0];
        let err = Compressed::from_parts(comp.cfg(), 2, 8, comp.vals().to_vec(), dup)
            .expect_err("duplicate index must be rejected");
        assert!(format!("{err:#}").contains("ascending"), "{err:#}");

        // Descending order within a group.
        let mut desc = good.clone();
        desc.swap(0, 1);
        let err = Compressed::from_parts(comp.cfg(), 2, 8, comp.vals().to_vec(), desc)
            .expect_err("descending indices must be rejected");
        assert!(format!("{err:#}").contains("ascending"), "{err:#}");

        // Entry stolen from the wrong group: 3 columns in group 0, 1 in
        // group 1 — counts are fine, structure is not.
        let mut wrong_group = good;
        // Entry slots 2..4 belong to group 1 (columns 4..8); point slot 2
        // back into group 0.
        wrong_group[2] = 0;
        let err = Compressed::from_parts(comp.cfg(), 2, 8, comp.vals().to_vec(), wrong_group)
            .expect_err("wrong-group index must be rejected");
        assert!(format!("{err:#}").contains("group"), "{err:#}");
    }

    #[test]
    fn indices_ascending_within_groups() {
        let mut rng = Pcg32::seeded(2);
        let (w, m) = sample(&mut rng, 4, 16, NmConfig::PAT_2_4);
        let comp = Compressed::compress(&w, &m);
        let k = comp.k();
        let idx = comp.idx();
        for r in 0..4 {
            let row = &idx[r * k..(r + 1) * k];
            for pair in row.chunks(2) {
                assert!(pair[0] < pair[1]);
            }
        }
    }
}
