//! N:M pruning masks.

use super::NmConfig;
use crate::tensor::Mat;

/// A {0,1} pruning mask over a `[C_out, C_in]` weight matrix, constructed
/// to satisfy an N:M pattern (paper Eq. 7: per group of `m` consecutive
/// input channels, exactly `keep` entries are 1).
#[derive(Debug, Clone, PartialEq)]
pub struct NmMask {
    cfg: NmConfig,
    bits: Vec<bool>,
    rows: usize,
    cols: usize,
}

impl NmMask {
    /// Select the mask that maximizes retained importance per group
    /// (Eq. 7): keep the `keep` largest `scores` in every group of `m`.
    /// Ties break toward the lower index (matches the jnp oracle).
    pub fn from_scores(scores: &Mat, cfg: NmConfig) -> NmMask {
        let (rows, cols) = scores.shape();
        assert_eq!(cols % cfg.m, 0, "C_in must be divisible by M");
        let mut bits = vec![false; rows * cols];
        let mut idx: Vec<usize> = Vec::with_capacity(cfg.m);
        for r in 0..rows {
            let srow = scores.row(r);
            for g in 0..cols / cfg.m {
                let base = g * cfg.m;
                idx.clear();
                idx.extend(0..cfg.m);
                // Stable sort descending by score -> lower index wins ties.
                idx.sort_by(|&a, &b| {
                    srow[base + b]
                        .partial_cmp(&srow[base + a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
                for &i in idx.iter().take(cfg.keep) {
                    bits[r * cols + base + i] = true;
                }
            }
        }
        NmMask { cfg, bits, rows, cols }
    }

    /// Build from an explicit dense 0/1 matrix (validated).
    pub fn from_dense(mask: &Mat, cfg: NmConfig) -> Option<NmMask> {
        let (rows, cols) = mask.shape();
        if cols % cfg.m != 0 {
            return None;
        }
        let bits: Vec<bool> = mask.data().iter().map(|&x| x != 0.0).collect();
        let out = NmMask { cfg, bits, rows, cols };
        if out.verify() {
            Some(out)
        } else {
            None
        }
    }

    pub fn cfg(&self) -> NmConfig {
        self.cfg
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn get(&self, r: usize, c: usize) -> bool {
        self.bits[r * self.cols + c]
    }

    /// Check the N:M invariant: every group has exactly `keep` ones.
    pub fn verify(&self) -> bool {
        for r in 0..self.rows {
            for g in 0..self.cols / self.cfg.m {
                let base = r * self.cols + g * self.cfg.m;
                let ones = self.bits[base..base + self.cfg.m].iter().filter(|&&b| b).count();
                if ones != self.cfg.keep {
                    return false;
                }
            }
        }
        true
    }

    /// Dense 0/1 matrix view.
    pub fn to_dense(&self) -> Mat {
        Mat::from_vec(
            self.rows,
            self.cols,
            self.bits.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect(),
        )
    }

    /// `mask ⊙ W`.
    pub fn apply(&self, w: &Mat) -> Mat {
        assert_eq!(w.shape(), (self.rows, self.cols));
        let data = w
            .data()
            .iter()
            .zip(&self.bits)
            .map(|(&x, &b)| if b { x } else { 0.0 })
            .collect();
        Mat::from_vec(self.rows, self.cols, data)
    }

    /// Sum of retained scores — the handcrafted CP quality metric `S`
    /// the paper argues against (Fig. 1); needed for the baselines.
    pub fn retained_score(&self, scores: &Mat) -> f64 {
        scores
            .data()
            .iter()
            .zip(&self.bits)
            .filter(|(_, &b)| b)
            .map(|(&s, _)| s as f64)
            .sum()
    }

    /// Column permutation of the mask (for un-permuting in Fig. 3 dumps):
    /// `out[:, j] = self[:, src_of[j]]`.
    pub fn permute_cols(&self, src_of: &[usize]) -> NmMask {
        // NOTE: the permuted mask generally no longer satisfies N:M —
        // that is the whole point of channel permutation (Eq. 12 keeps the
        // *stored* weight N:M; the logical original-order view is free-form).
        let mut bits = vec![false; self.bits.len()];
        for r in 0..self.rows {
            for (j, &i) in src_of.iter().enumerate() {
                bits[r * self.cols + j] = self.bits[r * self.cols + i];
            }
        }
        NmMask { cfg: self.cfg, bits, rows: self.rows, cols: self.cols }
    }

    /// Fraction of ones (should equal cfg.density()).
    pub fn density(&self) -> f32 {
        self.bits.iter().filter(|&&b| b).count() as f32 / self.bits.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit;

    #[test]
    fn keeps_largest_per_group() {
        let s = Mat::from_vec(1, 4, vec![0.1, 3.0, -2.0, 0.5]);
        let m = NmMask::from_scores(&s, NmConfig::PAT_2_4);
        assert!(!m.get(0, 0) && m.get(0, 1) && !m.get(0, 2) && m.get(0, 3));
    }

    #[test]
    fn tie_break_prefers_lower_index() {
        let s = Mat::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
        let m = NmMask::from_scores(&s, NmConfig::PAT_2_4);
        assert!(m.get(0, 0) && m.get(0, 1) && !m.get(0, 2) && !m.get(0, 3));
    }

    #[test]
    fn prop_mask_always_satisfies_nm() {
        testkit::check("nm-invariant", |rng| {
            let rows = 1 + rng.below_usize(8);
            let groups = 1 + rng.below_usize(8);
            for cfg in [NmConfig::PAT_2_4, NmConfig::PAT_4_8, NmConfig { m: 4, keep: 1 }] {
                let cols = groups * cfg.m;
                let s = Mat::randn(rows, cols, 1.0, rng);
                let m = NmMask::from_scores(&s, cfg);
                if !m.verify() {
                    return Err(format!("invariant broken for {:?}", cfg));
                }
                let d = m.density();
                if (d - cfg.density()).abs() > 1e-6 {
                    return Err(format!("density {d} != {}", cfg.density()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mask_maximizes_group_score() {
        // From-scores mask must retain at least as much score per group as
        // any random valid selection.
        testkit::check("mask-greedy-optimal", |rng| {
            let cfg = NmConfig::PAT_2_4;
            let s = Mat::randn(4, 16, 1.0, rng);
            let m = NmMask::from_scores(&s, cfg);
            let best = m.retained_score(&s);
            // Random alternative masks.
            for _ in 0..4 {
                let mut bits = Mat::zeros(4, 16);
                for r in 0..4 {
                    for g in 0..4 {
                        let mut cand: Vec<usize> = (0..4).collect();
                        rng.shuffle(&mut cand);
                        for &i in cand.iter().take(2) {
                            bits[(r, g * 4 + i)] = 1.0;
                        }
                    }
                }
                let alt = NmMask::from_dense(&bits, cfg).unwrap();
                if alt.retained_score(&s) > best + 1e-4 {
                    return Err("found better selection than argmax mask".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn apply_zeroes_pruned_entries() {
        let mut rng = Pcg32::seeded(5);
        let w = Mat::randn(4, 8, 1.0, &mut rng);
        let m = NmMask::from_scores(&w.map(f32::abs), NmConfig::PAT_2_4);
        let sparse = m.apply(&w);
        for r in 0..4 {
            for c in 0..8 {
                if m.get(r, c) {
                    assert_eq!(sparse[(r, c)], w[(r, c)]);
                } else {
                    assert_eq!(sparse[(r, c)], 0.0);
                }
            }
        }
    }

    #[test]
    fn from_dense_rejects_invalid() {
        let bad = Mat::full(1, 4, 1.0); // all ones is not 2:4
        assert!(NmMask::from_dense(&bad, NmConfig::PAT_2_4).is_none());
    }

    #[test]
    fn permute_cols_roundtrip() {
        let mut rng = Pcg32::seeded(6);
        let w = Mat::randn(3, 8, 1.0, &mut rng);
        let m = NmMask::from_scores(&w.map(f32::abs), NmConfig::PAT_2_4);
        let perm = rng.permutation(8);
        let mut inv = vec![0usize; 8];
        for (j, &i) in perm.iter().enumerate() {
            inv[i] = j;
        }
        let back = m.permute_cols(&perm).permute_cols(&inv);
        assert_eq!(back.to_dense().data(), m.to_dense().data());
    }
}
