//! N:M semi-structured sparsity: masks, compressed storage, sparse matmul.
//!
//! Paper notation (§2.2): "N:M sparsity" zeroes N of every M consecutive
//! input channels; `keep = M - N` survive per group. The NVIDIA 2:4
//! pattern is `NmConfig { m: 4, keep: 2 }`, 4:8 is `{ m: 8, keep: 4 }`.
//!
//! [`Compressed`] is the Sparse-Tensor-Core storage analogue: retained
//! values plus per-entry column metadata, halving weight bytes for 2:4 and
//! halving every inner product's length — the source of the paper's
//! Table 3 speedup (see `benches/table3_runtime.rs`).

mod mask;
mod compressed;

pub use compressed::Compressed;
pub use mask::NmMask;

/// An N:M sparsity pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NmConfig {
    /// Group size (consecutive input channels).
    pub m: usize,
    /// Retained entries per group (`M - N` in the paper's notation).
    pub keep: usize,
}

impl NmConfig {
    /// The 2:4 pattern natively supported by Ampere Sparse Tensor Cores.
    pub const PAT_2_4: NmConfig = NmConfig { m: 4, keep: 2 };
    /// The 4:8 pattern (paper Appendix B).
    pub const PAT_4_8: NmConfig = NmConfig { m: 8, keep: 4 };

    /// Fraction of weights retained.
    pub fn density(&self) -> f32 {
        self.keep as f32 / self.m as f32
    }

    /// Human-readable name in the paper's "zeros:group" convention.
    pub fn name(&self) -> String {
        format!("{}:{}", self.m - self.keep, self.m)
    }

    /// Parse "2:4"-style names (zeros:group).
    pub fn parse(s: &str) -> Option<NmConfig> {
        let (n, m) = s.split_once(':')?;
        let n: usize = n.trim().parse().ok()?;
        let m: usize = m.trim().parse().ok()?;
        if n >= m || m == 0 {
            return None;
        }
        Some(NmConfig { m, keep: m - n })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_parse_roundtrip() {
        assert_eq!(NmConfig::PAT_2_4.name(), "2:4");
        assert_eq!(NmConfig::PAT_4_8.name(), "4:8");
        assert_eq!(NmConfig::parse("2:4"), Some(NmConfig::PAT_2_4));
        assert_eq!(NmConfig::parse("4:8"), Some(NmConfig::PAT_4_8));
        assert_eq!(NmConfig::parse("4:4"), None);
        assert_eq!(NmConfig::parse("x"), None);
    }

    #[test]
    fn density() {
        assert_eq!(NmConfig::PAT_2_4.density(), 0.5);
        assert_eq!(NmConfig { m: 4, keep: 1 }.density(), 0.25);
    }
}
