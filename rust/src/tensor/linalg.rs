//! Dense linear algebra: Cholesky factorization and triangular solves.
//!
//! SparseGPT's OBS weight update needs `H^{-1}` for the damped Hessian
//! `H = X^T X + λI` (symmetric positive definite by construction); we
//! factor `H = L L^T` and form the inverse via two triangular solves,
//! matching the reference implementation's `torch.cholesky_inverse`.

use super::Mat;

/// Lower-triangular Cholesky factor `L` of an SPD matrix (`A = L L^T`).
///
/// Returns `None` if the matrix is not positive definite (non-positive
/// pivot) — callers add damping and retry.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "cholesky: square required");
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)] as f64;
            for k in 0..j {
                sum -= l[(i, k)] as f64 * l[(j, k)] as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[(i, j)] = (sum.sqrt()) as f32;
            } else {
                l[(i, j)] = (sum / l[(j, j)] as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Solve `L y = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l[(i, k)] as f64 * y[k] as f64;
        }
        y[i] = (sum / l[(i, i)] as f64) as f32;
    }
    y
}

/// Solve `L^T x = y` for lower-triangular `L` (backward substitution).
pub fn solve_upper(l: &Mat, y: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(y.len(), n);
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut sum = y[i] as f64;
        for k in i + 1..n {
            sum -= l[(k, i)] as f64 * x[k] as f64;
        }
        x[i] = (sum / l[(i, i)] as f64) as f32;
    }
    x
}

/// Full inverse of an SPD matrix via its Cholesky factor.
pub fn cholesky_inverse(a: &Mat) -> Option<Mat> {
    let n = a.rows();
    let l = cholesky(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_upper(&l, &y);
        for i in 0..n {
            inv[(i, j)] = x[i];
        }
        e[j] = 0.0;
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg32::seeded(seed);
        let x = Mat::randn(2 * n, n, 1.0, &mut rng);
        let mut h = x.matmul_at(&x); // X^T X
        for i in 0..n {
            h[(i, i)] += 0.5;
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd(8, 1);
        let l = cholesky(&a).unwrap();
        let recon = l.matmul_bt(&l); // L L^T
        assert!(recon.mse(&a) < 1e-6, "mse {}", recon.mse(&a));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solves_invert_factor() {
        let a = spd(6, 2);
        let l = cholesky(&a).unwrap();
        let b: Vec<f32> = (0..6).map(|i| i as f32 - 2.5).collect();
        let y = solve_lower(&l, &b);
        let x = solve_upper(&l, &y);
        // A x should equal b.
        for i in 0..6 {
            let ax: f32 = (0..6).map(|j| a[(i, j)] * x[j]).sum();
            assert!((ax - b[i]).abs() < 1e-3, "row {i}: {ax} vs {}", b[i]);
        }
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let a = spd(10, 3);
        let inv = cholesky_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.mse(&Mat::eye(10)) < 1e-5, "mse {}", prod.mse(&Mat::eye(10)));
    }
}
