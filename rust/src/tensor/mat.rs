//! Row-major f32 matrix.

use crate::util::rng::Pcg32;

/// Dense row-major matrix of f32.
///
/// Weights follow the paper's `[C_out, C_in]` convention: `rows = C_out`,
/// `cols = C_in`, and a linear layer computes `y = x W^T` for activation
/// rows `x: [T, C_in]` (see [`Mat::matmul_bt`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Wrap an existing buffer (length must be rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Mat { rows, cols, data }
    }

    /// Matrix whose initial contents are unspecified garbage.
    ///
    /// INVARIANT: the caller must overwrite every element before any
    /// element is read.  Reserved for kernels that produce fully-written
    /// outputs (`Compressed::matmul_xt_threads` writes every output
    /// element exactly once) — `Mat::zeros` would touch every output byte
    /// twice, once for the fill and once for the real value.
    pub fn uninit_filled(rows: usize, cols: usize) -> Self {
        let n = rows * cols;
        let mut data = Vec::with_capacity(n);
        debug_assert!(data.capacity() >= n, "with_capacity reserved less than requested");
        // SAFETY: `f32` is a plain-old-data type — every bit pattern is a
        // valid value, there is no drop glue, and the capacity was just
        // reserved.  The garbage values are never *used*: every caller
        // fully overwrites the buffer before reading (the invariant
        // above), so no computation ever depends on an indeterminate
        // value.
        unsafe { data.set_len(n) };
        Mat { rows, cols, data }
    }

    /// Gaussian-initialized matrix with standard deviation `std`.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Pcg32) -> Self {
        Mat { rows, cols, data: rng.normal_vec(rows * cols, std) }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Borrow row `r` as a slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Borrow row `r` mutably.
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of rows `[lo, hi)` as a new `[hi-lo, cols]` matrix (the
    /// serving path's per-request span extraction — see `crate::serve`).
    pub fn row_block(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows, "row block [{lo}, {hi}) out of range");
        let data = self.data[lo * self.cols..hi * self.cols].to_vec();
        Mat { rows: hi - lo, cols: self.cols, data }
    }

    /// Copy of column `c`.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// `self @ other` — [m,k] x [k,n] -> [m,n].
    ///
    /// ikj loop order with a row accumulator: the inner loop is a
    /// contiguous axpy over `other`'s row, which the compiler
    /// auto-vectorizes.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (l, &a) in arow.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[l * n..(l + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self @ other^T` — [m,k] x [n,k] -> [m,n].  The linear-layer product
    /// `y = x W^T`: both operands stream row-contiguously (dot products).
    pub fn matmul_bt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_bt shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            for j in 0..n {
                out[(i, j)] = dot(arow, other.row(j));
            }
        }
        out
    }

    /// `self^T @ other` — [k,m] x [k,n] -> [m,n] (Gram-style product,
    /// used for Hessian accumulation X^T X in SparseGPT).
    pub fn matmul_at(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_at shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for l in 0..k {
            let arow = self.row(l);
            let brow = &other.data[l * n..(l + 1) * n];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Element-wise product (same shape).
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Element-wise difference.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Element-wise sum.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// [`Mat::add`] into an existing matrix (every element overwritten;
    /// same element order as `add`) — the arena-backed serving hot path's
    /// residual-sum form.
    pub fn add_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.shape(), other.shape());
        assert_eq!(out.shape(), self.shape(), "add_into shape mismatch");
        for (o, (a, b)) in out.data.iter_mut().zip(self.data.iter().zip(&other.data)) {
            *o = a + b;
        }
    }

    /// Scaled copy.
    pub fn scale(&self, s: f32) -> Mat {
        let data = self.data.iter().map(|a| a * s).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Map a function over all elements.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        let data = self.data.iter().map(|&a| f(a)).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Mean squared difference vs another matrix.
    pub fn mse(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape());
        let n = self.data.len() as f32;
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n
    }

    /// L2 norm of every column (the `||X_j||_2` in Wanda's metric).
    pub fn col_l2_norms(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, &x) in out.iter_mut().zip(self.row(r)) {
                *o += x * x;
            }
        }
        for o in out.iter_mut() {
            *o = o.sqrt();
        }
        out
    }

    /// Permute columns: `out[:, j] = self[:, src_of[j]]` (this is `W @ P`
    /// with `P[src_of[j], j] = 1` — the paper's channel permutation).
    ///
    /// Hot path of the runtime permute (Table 3's CP column): indices are
    /// validated once, then the per-row gather runs without bounds checks
    /// (§Perf iteration 2).
    pub fn permute_cols(&self, src_of: &[usize]) -> Mat {
        assert_eq!(src_of.len(), self.cols);
        assert!(
            src_of.iter().all(|&i| i < self.cols),
            "permutation index out of range"
        );
        let mut out = Mat::uninit_filled(self.rows, self.cols);
        self.permute_cols_into(src_of, &mut out);
        out
    }

    /// [`Mat::permute_cols`] writing into an existing same-shape matrix —
    /// the zero-allocation form the arena-backed serving hot path uses
    /// (`out` is recycled scratch).  Every element of `out` is
    /// overwritten.
    pub fn permute_cols_into(&self, src_of: &[usize], out: &mut Mat) {
        assert_eq!(src_of.len(), self.cols);
        assert_eq!(out.shape(), self.shape(), "permute_cols_into shape mismatch");
        assert!(
            src_of.iter().all(|&i| i < self.cols),
            "permutation index out of range"
        );
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = out.row_mut(r);
            for (d, &i) in dst.iter_mut().zip(src_of) {
                debug_assert!(i < src.len(), "permutation index outside the checked range");
                // SAFETY: every index checked against `cols` above (and
                // re-asserted per element in debug builds).
                *d = unsafe { *src.get_unchecked(i) };
            }
        }
    }

    /// Permute rows: `out[i, :] = self[dst_to_src[i], :]` (row reorder used
    /// for Eq. 12's propagation to the preceding layer's output channels).
    pub fn permute_rows(&self, src_of: &[usize]) -> Mat {
        assert_eq!(src_of.len(), self.rows);
        let mut out = Mat::zeros(self.rows, self.cols);
        for (i, &s) in src_of.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(s));
        }
        out
    }

    /// Mean cosine distance between corresponding rows (paper Eq. 10).
    pub fn mean_cosine_distance(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape());
        let mut acc = 0.0f64;
        for r in 0..self.rows {
            let (a, b) = (self.row(r), other.row(r));
            let dot = dot(a, b);
            let na = dot_self(a).sqrt();
            let nb = dot_self(b).sqrt();
            acc += (1.0 - dot / (na * nb + 1e-8)) as f64;
        }
        (acc / self.rows as f64) as f32
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

/// Dense dot product (contiguous slices).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

fn dot_self(a: &[f32]) -> f32 {
    dot(a, a)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Mat {
        Mat::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn matmul_small() {
        let a = m(2, 2, &[1., 2., 3., 4.]);
        let b = m(2, 2, &[5., 6., 7., 8.]);
        assert_eq!(a.matmul(&b).data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn row_block_copies_the_span() {
        let a = m(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let b = a.row_block(1, 3);
        assert_eq!(b.shape(), (2, 2));
        assert_eq!(b.data(), &[3., 4., 5., 6.]);
        assert_eq!(a.row_block(1, 1).shape(), (0, 2));
    }

    #[test]
    fn matmul_bt_matches_matmul_of_transpose() {
        let mut rng = Pcg32::seeded(3);
        let a = Mat::randn(5, 7, 1.0, &mut rng);
        let b = Mat::randn(4, 7, 1.0, &mut rng);
        let via_bt = a.matmul_bt(&b);
        let via_t = a.matmul(&b.transpose());
        assert!(via_bt.mse(&via_t) < 1e-12);
    }

    #[test]
    fn matmul_at_matches_matmul_of_transpose() {
        let mut rng = Pcg32::seeded(4);
        let a = Mat::randn(6, 3, 1.0, &mut rng);
        let b = Mat::randn(6, 5, 1.0, &mut rng);
        let via_at = a.matmul_at(&b);
        let via_t = a.transpose().matmul(&b);
        assert!(via_at.mse(&via_t) < 1e-12);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg32::seeded(5);
        let a = Mat::randn(4, 4, 1.0, &mut rng);
        assert!(a.matmul(&Mat::eye(4)).mse(&a) < 1e-12);
        assert!(Mat::eye(4).matmul(&a).mse(&a) < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg32::seeded(6);
        let a = Mat::randn(3, 8, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn permute_cols_matches_matrix_product() {
        let mut rng = Pcg32::seeded(7);
        let a = Mat::randn(3, 6, 1.0, &mut rng);
        let src_of = rng.permutation(6);
        // P[src_of[j], j] = 1
        let mut p = Mat::zeros(6, 6);
        for (j, &i) in src_of.iter().enumerate() {
            p[(i, j)] = 1.0;
        }
        let got = a.permute_cols(&src_of);
        let want = a.matmul(&p);
        assert!(got.mse(&want) < 1e-12);
    }

    #[test]
    fn permute_cols_into_matches_allocating_form() {
        let mut rng = Pcg32::seeded(12);
        let a = Mat::randn(4, 6, 1.0, &mut rng);
        let src_of = rng.permutation(6);
        let want = a.permute_cols(&src_of);
        // Recycled scratch starts full of garbage; every element must be
        // overwritten.
        let mut out = Mat::full(4, 6, f32::NAN);
        a.permute_cols_into(&src_of, &mut out);
        assert_eq!(out.data(), want.data());
    }

    #[test]
    fn uninit_filled_has_the_right_shape() {
        let m = Mat::uninit_filled(3, 5);
        assert_eq!(m.shape(), (3, 5));
        assert_eq!(m.data().len(), 15);
    }

    #[test]
    fn permute_rows_then_inverse_is_identity() {
        let mut rng = Pcg32::seeded(8);
        let a = Mat::randn(6, 3, 1.0, &mut rng);
        let src_of = rng.permutation(6);
        let mut inv = vec![0usize; 6];
        for (j, &i) in src_of.iter().enumerate() {
            inv[i] = j;
        }
        let back = a.permute_rows(&src_of).permute_rows(&inv);
        assert!(back.mse(&a) < 1e-12);
    }

    #[test]
    fn col_l2_norms_match_naive() {
        let a = m(2, 3, &[3., 0., 1., 4., 0., 1.]);
        let norms = a.col_l2_norms();
        assert!((norms[0] - 5.0).abs() < 1e-6);
        assert!(norms[1].abs() < 1e-6);
        assert!((norms[2] - 2f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn cosine_distance_zero_for_equal() {
        let mut rng = Pcg32::seeded(9);
        let a = Mat::randn(4, 9, 1.0, &mut rng);
        assert!(a.mean_cosine_distance(&a) < 1e-6);
    }

    #[test]
    fn cosine_distance_positive_for_different() {
        let mut rng = Pcg32::seeded(10);
        let a = Mat::randn(4, 9, 1.0, &mut rng);
        let b = Mat::randn(4, 9, 1.0, &mut rng);
        assert!(a.mean_cosine_distance(&b) > 0.1);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_checked() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
