//! Minimal dense f32 host tensor substrate.
//!
//! Everything the pruning stack needs on the host: a row-major 2-D matrix
//! with matmul (all transpose variants), row/column utilities, norms, and
//! the Cholesky factorization SparseGPT's OBS update requires.  Kept
//! deliberately small — the heavy lifting at scale happens inside the AOT
//! XLA artifacts; this type exists for calibration math, pruning metrics,
//! and the pure-Rust LCP path.

mod mat;
mod linalg;

pub use linalg::{cholesky, cholesky_inverse, solve_lower, solve_upper};
pub use mat::Mat;
