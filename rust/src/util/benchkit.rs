//! Benchmark harness substrate (the offline registry has no criterion).
//!
//! Criterion-like discipline for `harness = false` bench binaries: warmup,
//! N timed iterations, mean/p50/p95 reporting, and machine-readable JSON
//! appended to `bench_results/`. Every paper table/figure bench is built
//! on [`Bench`] + [`Table`].

use std::time::{Duration, Instant};

use crate::util::json::{arr, num, obj, s, Json};

/// Timing statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

/// Micro-benchmark runner.
pub struct Bench {
    warmup: usize,
    iters: usize,
    max_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        // PERMLLM_BENCH_FAST=1 trims iterations for CI-style smoke runs.
        let fast = std::env::var("PERMLLM_BENCH_FAST").is_ok();
        Bench {
            warmup: if fast { 1 } else { 3 },
            iters: if fast { 5 } else { 30 },
            max_time: Duration::from_secs(if fast { 5 } else { 20 }),
        }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters, max_time: Duration::from_secs(60) }
    }

    /// Time `f`, returning stats. `f` should return something observable
    /// (its result is black-boxed to keep the optimizer honest).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        let start = Instant::now();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
            if start.elapsed() > self.max_time {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        Stats {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            p50_ns: samples[n / 2],
            p95_ns: samples[(n as f64 * 0.95) as usize % n.max(1)],
            min_ns: samples[0],
        }
    }
}

/// Plain-text table printer that mirrors the paper's row/column layout.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout with aligned columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut out = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                out.push_str(&format!("{c:<width$}  ", width = w));
            }
            println!("{}", out.trim_end());
        };
        line(&self.header);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }

    /// Serialize as JSON for bench_results/.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("title", s(&self.title)),
            ("header", arr(self.header.iter().map(|h| s(h)).collect())),
            (
                "rows",
                arr(self
                    .rows
                    .iter()
                    .map(|r| arr(r.iter().map(|c| s(c)).collect()))
                    .collect()),
            ),
        ])
    }

    /// Print and persist under `bench_results/<file>.json`.
    pub fn finish(&self, file: &str) {
        self.print();
        let dir = std::path::Path::new("bench_results");
        let _ = std::fs::create_dir_all(dir);
        let mut payload = self.to_json();
        if let Json::Obj(ref mut o) = payload {
            o.insert("unix_time".into(), num(now_unix()));
        }
        let path = dir.join(format!("{file}.json"));
        if let Err(e) = std::fs::write(&path, payload.to_string()) {
            eprintln!("warn: could not write {}: {e}", path.display());
        } else {
            println!("[saved {}]", path.display());
        }
    }
}

fn now_unix() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Format a float with fixed decimals for table cells.
pub fn fmt(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let b = Bench::new(1, 10);
        let st = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(st.iters, 10);
        assert!(st.mean_ns > 0.0);
        assert!(st.min_ns <= st.p50_ns);
        assert!(st.p50_ns <= st.p95_ns * 1.001);
    }

    #[test]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()]);
        }));
        assert!(res.is_err());
    }

    #[test]
    fn table_json_shape() {
        let mut t = Table::new("title", &["c1"]);
        t.row(&["v".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("title"));
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }
}
