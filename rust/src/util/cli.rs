//! Tiny CLI argument parser substrate (the offline registry has no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and auto-generated `--help` text. Used by the `permllm`
//! binary and every example.

use std::collections::BTreeMap;

/// Declarative CLI: register options, then parse.
pub struct Cli {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

struct OptSpec {
    key: String,
    default: Option<String>,
    help: String,
    is_bool: bool,
}

impl Cli {
    pub fn new(name: &str, about: &str) -> Self {
        Cli {
            name: name.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            values: BTreeMap::new(),
            positionals: Vec::new(),
        }
    }

    /// Register `--key <value>` with a default.
    pub fn opt(mut self, key: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            key: key.to_string(),
            default: Some(default.to_string()),
            help: help.to_string(),
            is_bool: false,
        });
        self
    }

    /// Register a required `--key <value>` (no default).
    pub fn req(mut self, key: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            key: key.to_string(),
            default: None,
            help: help.to_string(),
            is_bool: false,
        });
        self
    }

    /// Register a boolean `--key` switch (default false).
    pub fn flag(mut self, key: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            key: key.to_string(),
            default: Some("false".to_string()),
            help: help.to_string(),
            is_bool: true,
        });
        self
    }

    fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.opts {
            let arg = if o.is_bool { format!("--{}", o.key) } else { format!("--{} <v>", o.key) };
            let def = match &o.default {
                Some(d) if !o.is_bool => format!(" [default: {d}]"),
                _ => String::new(),
            };
            out.push_str(&format!("  {arg:<24} {}{def}\n", o.help));
        }
        out
    }

    /// Parse an explicit argument list (no program name). Returns an error
    /// string on unknown/malformed flags; prints usage + exits on --help.
    pub fn parse_from(mut self, args: &[String]) -> Result<Parsed, String> {
        let known: BTreeMap<String, bool> =
            self.opts.iter().map(|o| (o.key.clone(), o.is_bool)).collect();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                print!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let is_bool = *known.get(&key).ok_or(format!("unknown option --{key}"))?;
                let value = if is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    args.get(i).cloned().ok_or(format!("--{key} needs a value"))?
                };
                self.values.insert(key, value);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        // Fill defaults, detect missing required options.
        let mut out = BTreeMap::new();
        for o in &self.opts {
            match self.values.get(&o.key).cloned().or_else(|| o.default.clone()) {
                Some(v) => {
                    out.insert(o.key.clone(), v);
                }
                None => return Err(format!("missing required option --{}", o.key)),
            }
        }
        Ok(Parsed { values: out, positionals: self.positionals })
    }

    /// Parse `std::env::args()`.
    pub fn parse(self) -> Result<Parsed, String> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        self.parse_from(&args)
    }
}

/// Parsed CLI values with typed getters.
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, key: &str) -> &str {
        self.values.get(key).map(|s| s.as_str()).unwrap_or_else(|| panic!("unregistered option {key}"))
    }

    pub fn get_usize(&self, key: &str) -> usize {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }

    pub fn get_u64(&self, key: &str) -> u64 {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }

    pub fn get_f32(&self, key: &str) -> f32 {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be a number"))
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), "true" | "1" | "yes")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("alpha", "1.5", "a number")
            .opt("name", "x", "a string")
            .flag("verbose", "switch")
            .req("model", "required path")
    }

    #[test]
    fn defaults_and_required() {
        let p = cli().parse_from(&args(&["--model", "m.bin"])).unwrap();
        assert_eq!(p.get_f32("alpha"), 1.5);
        assert_eq!(p.get("name"), "x");
        assert!(!p.get_bool("verbose"));
        assert_eq!(p.get("model"), "m.bin");
    }

    #[test]
    fn equals_form_and_flags() {
        let p = cli().parse_from(&args(&["--model=a", "--alpha=2", "--verbose"])).unwrap();
        assert_eq!(p.get_f32("alpha"), 2.0);
        assert!(p.get_bool("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse_from(&args(&["--alpha", "3"])).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(cli().parse_from(&args(&["--model", "m", "--nope", "1"])).is_err());
    }

    #[test]
    fn positionals_collected() {
        let p = cli().parse_from(&args(&["--model", "m", "pos1", "pos2"])).unwrap();
        assert_eq!(p.positionals, vec!["pos1", "pos2"]);
    }
}
