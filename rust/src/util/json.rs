//! Tiny JSON substrate (the offline registry has no serde).
//!
//! Covers exactly what the repo needs: parsing the AOT `manifest.json`
//! written by python/compile/aot.py, and serializing benchmark/experiment
//! results. Not a general-purpose JSON library — but a correct subset
//! (strings with escapes, numbers, bools, null, arrays, objects).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `obj["a"]["b"]`-style path access.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for emitting results without constructing maps by hand.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("invalid utf8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"ok":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\u{0007}".into());
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn builders() {
        let j = obj(vec![("x", num(1.0)), ("y", arr(vec![s("a")]))]);
        assert_eq!(j.to_string(), r#"{"x":1,"y":["a"]}"#);
    }
}
