//! Logging init: level selection for the in-repo `log` shim.
//!
//! The shim (`shims/log`) ships its own timestamped stderr backend, so
//! all this wrapper does is pick the level.  `permllm` and the examples
//! call [`init`] once; `RUST_LOG`-style level selection via the
//! `PERMLLM_LOG` env var (error|warn|info|debug|trace, default info).

use log::LevelFilter;

/// Install the log level (idempotent).
pub fn init() {
    let level = match std::env::var("PERMLLM_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
