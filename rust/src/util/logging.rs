//! Minimal `log` backend: timestamped stderr logging.
//!
//! `permllm` and the examples call [`init`] once; `RUST_LOG`-style level
//! selection via the `PERMLLM_LOG` env var (error|warn|info|debug|trace,
//! default info).

use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::OnceCell;

static START: OnceCell<Instant> = OnceCell::new();

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent).
pub fn init() {
    let _ = START.set(Instant::now());
    let level = match std::env::var("PERMLLM_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
