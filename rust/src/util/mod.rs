//! Infrastructure substrates built in-repo because the offline registry
//! lacks the usual crates (DESIGN.md §6): PRNG (`rand`), JSON (`serde`),
//! CLI (`clap`), thread pool (`tokio`/`rayon`), property testing
//! (`proptest`), bench harness (`criterion`), logging backend
//! (`env_logger`).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod scratch;
pub mod testkit;
