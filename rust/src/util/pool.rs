//! Thread-pool substrate (the offline registry has no tokio/rayon).
//!
//! The coordinator fans LCP layer jobs out across workers with
//! [`parallel_map`]; it uses scoped threads so jobs can borrow calibration
//! data without `Arc` gymnastics. On this testbed `nproc` is often 1 —
//! the pool degrades gracefully to sequential execution.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default (`PERMLLM_THREADS` override).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PERMLLM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item index in parallel, collecting results in order.
///
/// Work-stealing via a shared atomic counter: each worker claims the next
/// unprocessed index, so heterogeneous job costs (layers of different
/// shapes) balance automatically.
///
/// A panicking job does not poison the pool with a generic join error:
/// the worker catches the unwind, remaining workers stop claiming new
/// jobs, and the original panic payload is re-raised on the caller's
/// thread — so `parallel_map(n, k, f)` fails with the same message a
/// plain `(0..n).map(f)` would.
pub fn parallel_map<T, F>(n_items: usize, n_threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = n_threads.max(1).min(n_items.max(1));
    if threads <= 1 || n_items <= 1 {
        return (0..n_items).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    type Slot<T> = Mutex<Option<std::thread::Result<T>>>;
    let results: Vec<Slot<T>> = (0..n_items).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                let out = catch_unwind(AssertUnwindSafe(|| f(i)));
                let panicked = out.is_err();
                *results[i].lock().unwrap() = Some(out);
                if panicked {
                    // Park the counter so no worker claims further jobs.
                    next.store(n_items, Ordering::Relaxed);
                    break;
                }
            });
        }
    });
    // Claim order is monotonic, so any abandoned (None) slot has a higher
    // index than every completed one; scanning in order therefore hits a
    // stored panic payload before any abandoned slot.
    let mut out = Vec::with_capacity(n_items);
    for slot in results {
        match slot.into_inner().unwrap() {
            Some(Ok(v)) => out.push(v),
            Some(Err(payload)) => resume_unwind(payload),
            None => unreachable!("job abandoned without a stored panic"),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicU32::new(0);
        let out = parallel_map(57, 3, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 57);
        assert_eq!(out.len(), 57);
    }

    #[test]
    fn sequential_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn borrows_environment() {
        let data = vec![10u32, 20, 30];
        let out = parallel_map(3, 2, |i| data[i] * 2);
        assert_eq!(out, vec![20, 40, 60]);
    }

    #[test]
    fn panic_payload_propagates_from_worker() {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map(8, 3, |i| {
                if i == 5 {
                    panic!("job {i} exploded");
                }
                i * 2
            })
        }));
        let payload = res.expect_err("parallel_map should have panicked");
        let msg = payload.downcast_ref::<String>().expect("formatted panic payload");
        assert!(msg.contains("job 5 exploded"), "payload lost: {msg}");
    }

    #[test]
    fn panic_payload_propagates_sequentially() {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map(3, 1, |i| {
                if i == 1 {
                    panic!("sequential boom");
                }
                i
            })
        }));
        let payload = res.expect_err("sequential path should have panicked");
        let msg = payload.downcast_ref::<&str>().expect("static panic payload");
        assert!(msg.contains("sequential boom"));
    }
}
