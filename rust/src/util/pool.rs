//! Thread-pool substrate (the offline registry has no tokio/rayon).
//!
//! The coordinator fans LCP layer jobs out across workers with
//! [`parallel_map`]; it uses scoped threads so jobs can borrow calibration
//! data without `Arc` gymnastics. On this testbed `nproc` is often 1 —
//! the pool degrades gracefully to sequential execution.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

/// Number of worker threads to use by default (`PERMLLM_THREADS` override).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PERMLLM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item index in parallel, collecting results in order.
///
/// Work-stealing via a shared atomic counter: each worker claims the next
/// unprocessed index, so heterogeneous job costs (layers of different
/// shapes) balance automatically.
///
/// A panicking job does not poison the pool with a generic join error:
/// the worker catches the unwind, remaining workers stop claiming new
/// jobs, and the original panic payload is re-raised on the caller's
/// thread — so `parallel_map(n, k, f)` fails with the same message a
/// plain `(0..n).map(f)` would.
pub fn parallel_map<T, F>(n_items: usize, n_threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = n_threads.max(1).min(n_items.max(1));
    if threads <= 1 || n_items <= 1 {
        return (0..n_items).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    type Slot<T> = Mutex<Option<std::thread::Result<T>>>;
    let results: Vec<Slot<T>> = (0..n_items).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                let out = catch_unwind(AssertUnwindSafe(|| f(i)));
                let panicked = out.is_err();
                *results[i].lock().unwrap() = Some(out);
                if panicked {
                    // Park the counter so no worker claims further jobs.
                    next.store(n_items, Ordering::Relaxed);
                    break;
                }
            });
        }
    });
    // Claim order is monotonic, so any abandoned (None) slot has a higher
    // index than every completed one; scanning in order therefore hits a
    // stored panic payload before any abandoned slot.
    let mut out = Vec::with_capacity(n_items);
    for slot in results {
        match slot.into_inner().unwrap() {
            Some(Ok(v)) => out.push(v),
            Some(Err(payload)) => resume_unwind(payload),
            None => unreachable!("job abandoned without a stored panic"),
        }
    }
    out
}

/// Run every input through a chain of stages with *cross-stage
/// pipelining*: each stage runs on its own worker thread connected to its
/// neighbours by channels, so stage `s` processes item `i` while stage
/// `s+1` is still busy with item `i-1` — the wavefront schedule behind
/// the serving subsystem's cross-layer overlap ([`crate::serve`]).
///
/// Each stage is an `FnMut` that *owns* its captured state (e.g. an
/// execution backend) for the whole run, so no locking happens on the hot
/// path.  Outputs come back in input order — channels are FIFO and the
/// chain is linear.
///
/// A panicking stage tears the pipeline down (upstream sends fail,
/// downstream channels close) and the original panic payload is re-raised
/// on the caller's thread, mirroring [`parallel_map`]'s contract.
pub fn pipeline_map<T, S>(inputs: Vec<T>, stages: Vec<S>) -> Vec<T>
where
    T: Send,
    S: FnMut(T) -> T + Send,
{
    let mut stages = stages;
    if stages.is_empty() {
        return inputs;
    }
    if stages.len() == 1 || inputs.len() <= 1 {
        // Nothing to overlap: run each item through the chain in place.
        let mut out = Vec::with_capacity(inputs.len());
        for mut item in inputs {
            for stage in stages.iter_mut() {
                item = stage(item);
            }
            out.push(item);
        }
        return out;
    }
    let panic_slot: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    let collected = std::thread::scope(|scope| {
        let (head_tx, mut prev_rx) = mpsc::channel::<T>();
        for mut stage in stages {
            let (tx, rx) = mpsc::channel::<T>();
            let rx_in = prev_rx;
            prev_rx = rx;
            let slot = &panic_slot;
            scope.spawn(move || {
                for item in rx_in {
                    match catch_unwind(AssertUnwindSafe(|| stage(item))) {
                        Ok(out) => {
                            if tx.send(out).is_err() {
                                break; // downstream died; stop early
                            }
                        }
                        Err(payload) => {
                            let mut guard = slot.lock().unwrap();
                            if guard.is_none() {
                                *guard = Some(payload);
                            }
                            break; // drops rx_in/tx, tearing the chain down
                        }
                    }
                }
            });
        }
        // Feed from the caller's thread; a send error means the first
        // stage already died, which the panic slot will explain.
        for item in inputs {
            if head_tx.send(item).is_err() {
                break;
            }
        }
        drop(head_tx);
        prev_rx.into_iter().collect::<Vec<T>>()
    });
    if let Some(payload) = panic_slot.into_inner().unwrap() {
        resume_unwind(payload);
    }
    collected
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicU32::new(0);
        let out = parallel_map(57, 3, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 57);
        assert_eq!(out.len(), 57);
    }

    #[test]
    fn sequential_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn borrows_environment() {
        let data = vec![10u32, 20, 30];
        let out = parallel_map(3, 2, |i| data[i] * 2);
        assert_eq!(out, vec![20, 40, 60]);
    }

    #[test]
    fn panic_payload_propagates_from_worker() {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map(8, 3, |i| {
                if i == 5 {
                    panic!("job {i} exploded");
                }
                i * 2
            })
        }));
        let payload = res.expect_err("parallel_map should have panicked");
        let msg = payload.downcast_ref::<String>().expect("formatted panic payload");
        assert!(msg.contains("job 5 exploded"), "payload lost: {msg}");
    }

    #[test]
    fn panic_payload_propagates_sequentially() {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map(3, 1, |i| {
                if i == 1 {
                    panic!("sequential boom");
                }
                i
            })
        }));
        let payload = res.expect_err("sequential path should have panicked");
        let msg = payload.downcast_ref::<&str>().expect("static panic payload");
        assert!(msg.contains("sequential boom"));
    }

    #[test]
    fn pipeline_chains_stages_in_input_order() {
        type Stage = Box<dyn FnMut(i64) -> i64 + Send>;
        let stages: Vec<Stage> =
            vec![Box::new(|v| v + 1), Box::new(|v| v * 10), Box::new(|v| v - 3)];
        let out = pipeline_map((0..20).collect(), stages);
        assert_eq!(out, (0..20).map(|v| (v + 1) * 10 - 3).collect::<Vec<i64>>());
    }

    #[test]
    fn pipeline_stages_keep_private_mutable_state() {
        // Each stage owns a counter; every item passes through every stage
        // exactly once and items stay ordered.
        type Stage = Box<dyn FnMut(Vec<u32>) -> Vec<u32> + Send>;
        let stages: Vec<Stage> = (0..3)
            .map(|s| {
                let mut seen = 0u32;
                Box::new(move |mut item: Vec<u32>| {
                    item.push(s * 100 + seen);
                    seen += 1;
                    item
                }) as Stage
            })
            .collect();
        let out = pipeline_map((0..5).map(|i| vec![i]).collect(), stages);
        for (i, item) in out.iter().enumerate() {
            let i = i as u32;
            assert_eq!(item, &vec![i, i, 100 + i, 200 + i]);
        }
    }

    #[test]
    fn pipeline_empty_stages_and_inputs() {
        let stages: Vec<fn(usize) -> usize> = vec![];
        assert_eq!(pipeline_map(vec![1, 2, 3], stages), vec![1, 2, 3]);
        let out: Vec<usize> = pipeline_map(vec![], vec![|v: usize| v + 1]);
        assert!(out.is_empty());
    }

    #[test]
    fn pipeline_single_item_runs_whole_chain() {
        let stages: Vec<fn(usize) -> usize> = vec![|v| v * 2, |v| v + 1];
        let out = pipeline_map(vec![7usize], stages);
        assert_eq!(out, vec![15]);
    }

    #[test]
    fn pipeline_panic_payload_propagates() {
        type Stage = Box<dyn FnMut(usize) -> usize + Send>;
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let stages: Vec<Stage> = vec![
                Box::new(|v| v + 1),
                Box::new(|v| {
                    if v == 3 {
                        panic!("stage 1 choked on {v}");
                    }
                    v
                }),
            ];
            pipeline_map((0..8).collect::<Vec<usize>>(), stages)
        }));
        let payload = res.expect_err("pipeline should have panicked");
        let msg = payload.downcast_ref::<String>().expect("formatted panic payload");
        assert!(msg.contains("stage 1 choked on 3"), "payload lost: {msg}");
    }
}
