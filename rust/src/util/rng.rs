//! Deterministic PRNG substrate (the offline registry has no `rand`).
//!
//! PCG32 (O'Neill 2014): 64-bit state, 32-bit output, XSH-RR output
//! function. Every stochastic component in the repo (data generation,
//! weight synthesis, property tests) takes an explicit [`Pcg32`] so runs
//! are reproducible from a single seed recorded in EXPERIMENTS.md.

/// PCG32 XSH-RR generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with a state and stream id (any values are valid).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-seed constructor (stream 54).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        // 24 mantissa-ish bits; exact representation in f32.
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.uniform() * (hi - lo)
    }

    /// Unbiased uniform integer in [0, n) (Lemire rejection).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let lo = m as u32;
            if lo >= n && lo < n.wrapping_neg() % n + n {
                // fall through to retry only in the biased band
            }
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in [0, n).
    pub fn below_usize(&mut self, n: usize) -> usize {
        assert!(n <= u32::MAX as usize);
        self.below(n as u32) as usize
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        let mut u1 = self.uniform();
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vector of standard normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of 0..n (as `src_of` indices).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (inverse-CDF on
    /// precomputed weights is the caller's job for hot loops; this is the
    /// simple rejection-free cumulative scan used by the corpus generators).
    pub fn zipf(&mut self, cdf: &[f32]) -> usize {
        let u = self.uniform();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

/// Precompute a Zipf CDF over `n` ranks with exponent `s`.
pub fn zipf_cdf(n: usize, s: f32) -> Vec<f32> {
    let mut w: Vec<f32> = (1..=n).map(|k| (k as f32).powf(-s)).collect();
    let total: f32 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / total;
        *x = acc;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::seeded(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let xs = r.normal_vec(20_000, 1.0);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Pcg32::seeded(13);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn zipf_cdf_monotone_ends_at_one() {
        let cdf = zipf_cdf(100, 1.1);
        for w in cdf.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!((cdf[99] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn zipf_rank_one_most_frequent() {
        let cdf = zipf_cdf(50, 1.2);
        let mut r = Pcg32::seeded(17);
        let mut counts = vec![0usize; 50];
        for _ in 0..20_000 {
            counts[r.zipf(&cdf)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[40]);
    }
}
