//! Double-buffered step arena for the serving hot path.
//!
//! The decode loop produces and consumes a burst of short-lived `f32`
//! buffers every batch step (activations, attention scratch, micro-batch
//! assembly).  Allocating them fresh each step puts the global allocator
//! on the per-token critical path; this module replaces that traffic with
//! two reusable pools that swap roles once per step — the `bozbez__nessie`
//! chain-builder idiom (SNIPPETS.md 1–2) applied to activation scratch.
//!
//! Lifecycle per step:
//!
//! 1. [`StepArena::take`] hands out a buffer from the **active** pool
//!    (best-fit by capacity, zero-filled to the requested shape).  Only
//!    when no pooled buffer has enough capacity does it allocate — a
//!    *grow event*, counted in [`StepArena::grow_events`].
//! 2. [`StepArena::give`] returns a finished buffer to the **standby**
//!    pool, where it sits out the rest of the step (so a buffer can never
//!    be re-handed-out while a caller still reads a view derived from the
//!    values it held).
//! 3. [`StepArena::step`] swaps the pools at the step boundary: everything
//!    given back becomes reusable capacity for the next step.
//!
//! Capacity is grow-only: after a warmup step at the steady-state batch
//! shape, every `take` is satisfied from the pools and the hot path makes
//! **zero heap allocations** (the property the `decode_allocs_per_step`
//! bench gate pins).  Scratch grows only when a step needs more concurrent
//! live buffers, or larger ones, than any step before it — e.g. a longer
//! prefill chunk or a wider micro-batch.
//!
//! # Example
//!
//! ```
//! use permllm::util::scratch::StepArena;
//!
//! let mut arena = StepArena::new();
//!
//! // Step 1: the pool is empty, so the first take allocates (grow event).
//! let a = arena.take(4, 8);
//! assert_eq!(a.shape(), (4, 8));
//! assert_eq!(arena.grow_events(), 1);
//! arena.give(a);
//! arena.step();
//!
//! // Step 2: same shape — served from the recycled buffer, no growth.
//! let b = arena.take(4, 8);
//! assert_eq!(arena.grow_events(), 1);
//! arena.give(b);
//! arena.step();
//! ```

use crate::tensor::Mat;

/// Two reusable pools of `f32` buffers that swap roles once per batch
/// step.  See the [module docs](self) for the lifecycle.
#[derive(Debug, Default)]
pub struct StepArena {
    /// Buffers available for `take` during the current step.
    active: Vec<Vec<f32>>,
    /// Buffers given back this step; promoted to `active` at `step()`.
    standby: Vec<Vec<f32>>,
    /// Times `take`/`take_vec` had to hit the global allocator.
    grows: u64,
}

impl StepArena {
    /// An empty arena.  The first step at any working-set shape grows it;
    /// subsequent steps at the same shape are allocation-free.
    pub fn new() -> Self {
        Self::default()
    }

    /// A zero-filled `[rows, cols]` matrix backed by pooled storage when
    /// a pooled buffer with enough capacity exists (best fit, smallest
    /// sufficient capacity), freshly allocated otherwise (a grow event).
    pub fn take(&mut self, rows: usize, cols: usize) -> Mat {
        let v = self.take_vec(rows * cols);
        Mat::from_vec(rows, cols, v)
    }

    /// The raw-buffer form of [`StepArena::take`]: a `Vec<f32>` of
    /// exactly `n` zeros.
    pub fn take_vec(&mut self, n: usize) -> Vec<f32> {
        let mut best: Option<(usize, usize)> = None;
        for (i, buf) in self.active.iter().enumerate() {
            let cap = buf.capacity();
            if cap >= n && best.is_none_or(|(_, c)| cap < c) {
                best = Some((i, cap));
            }
        }
        match best {
            Some((i, _)) => {
                let mut v = self.active.swap_remove(i);
                v.clear();
                v.resize(n, 0.0);
                v
            }
            None => {
                self.grows += 1;
                vec![0.0; n]
            }
        }
    }

    /// Return a matrix's storage to the standby pool for reuse from the
    /// *next* step onward.
    pub fn give(&mut self, m: Mat) {
        self.give_vec(m.into_vec());
    }

    /// Return a raw buffer to the standby pool.
    pub fn give_vec(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.standby.push(v);
        }
    }

    /// Step boundary: buffers given back this step become available for
    /// the next one.  Buffers still in `active` (taken last step but
    /// never re-taken this step) are kept too — capacity is grow-only.
    pub fn step(&mut self) {
        std::mem::swap(&mut self.active, &mut self.standby);
        // Whatever the (now-)standby side still holds is idle capacity;
        // fold it into the active pool rather than stranding it a step.
        let leftovers = std::mem::take(&mut self.standby);
        self.active.extend(leftovers);
    }

    /// How many times a `take` could not be served from the pools and
    /// had to allocate.  Flat across steady-state steps ⇔ the hot path
    /// is allocation-free.
    pub fn grow_events(&self) -> u64 {
        self.grows
    }

    /// Buffers currently pooled (both sides) — a capacity gauge.
    pub fn pooled(&self) -> usize {
        self.active.len() + self.standby.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_reuses_without_growing() {
        let mut arena = StepArena::new();
        // Warmup step: three live buffers of mixed shapes.
        let a = arena.take(4, 8);
        let b = arena.take(2, 8);
        let v = arena.take_vec(5);
        assert_eq!(arena.grow_events(), 3);
        arena.give(a);
        arena.give(b);
        arena.give_vec(v);
        arena.step();
        // Steady state: same working set, served entirely from the pool.
        for _ in 0..10 {
            let a = arena.take(4, 8);
            let b = arena.take(2, 8);
            let v = arena.take_vec(5);
            assert_eq!(a.shape(), (4, 8));
            assert!(a.data().iter().all(|&x| x == 0.0));
            arena.give(a);
            arena.give(b);
            arena.give_vec(v);
            arena.step();
        }
        assert_eq!(arena.grow_events(), 3);
        assert_eq!(arena.pooled(), 3);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_buffer() {
        let mut arena = StepArena::new();
        arena.give_vec(Vec::with_capacity(100));
        arena.give_vec(Vec::with_capacity(10));
        arena.step();
        // Needs 8: the capacity-10 buffer is the best fit, leaving the
        // capacity-100 one for a larger request.
        let v = arena.take_vec(8);
        assert!(v.capacity() < 100);
        let w = arena.take_vec(64);
        assert_eq!(arena.grow_events(), 0);
        arena.give_vec(v);
        arena.give_vec(w);
    }

    #[test]
    fn buffers_given_this_step_are_not_rehanded_until_next() {
        let mut arena = StepArena::new();
        let a = arena.take_vec(16);
        arena.give_vec(a);
        // Same step: the standby side must not serve it.
        let b = arena.take_vec(16);
        assert_eq!(arena.grow_events(), 2);
        arena.give_vec(b);
        arena.step();
        let _ = arena.take_vec(16);
        assert_eq!(arena.grow_events(), 2);
    }

    #[test]
    fn taken_buffers_are_zeroed_even_after_reuse() {
        let mut arena = StepArena::new();
        let mut a = arena.take(2, 3);
        a.data_mut().fill(7.5);
        arena.give(a);
        arena.step();
        let b = arena.take(2, 3);
        assert!(b.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn leftover_active_capacity_survives_step() {
        let mut arena = StepArena::new();
        let a = arena.take_vec(4);
        arena.give_vec(a);
        arena.step();
        // This step never takes the buffer; it must still be pooled after
        // the next boundary.
        arena.step();
        let _ = arena.take_vec(4);
        assert_eq!(arena.grow_events(), 1);
    }
}
