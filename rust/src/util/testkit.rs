//! Property-testing substrate (the offline registry has no proptest).
//!
//! Proptest-like discipline with the pieces we actually use: seeded case
//! generation from [`Pcg32`], N-case sweeps, and failure reporting that
//! includes the per-case seed so any counterexample replays with
//! `case_rng(seed)`. No shrinking — cases are kept small instead.

use crate::util::rng::Pcg32;

/// Number of cases per property (override with `PERMLLM_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("PERMLLM_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32)
}

/// Deterministic per-case RNG.
pub fn case_rng(case: u64) -> Pcg32 {
    Pcg32::new(0x9E3779B97F4A7C15 ^ case, case.wrapping_mul(2) + 1)
}

/// Run `prop` for `default_cases()` seeded cases; panic with the seed of
/// the first failing case.
pub fn check<F>(name: &str, prop: F)
where
    F: Fn(&mut Pcg32) -> Result<(), String>,
{
    check_n(name, default_cases(), prop)
}

/// Run `prop` for exactly `n` cases.
pub fn check_n<F>(name: &str, n: u64, prop: F)
where
    F: Fn(&mut Pcg32) -> Result<(), String>,
{
    for case in 0..n {
        let mut rng = case_rng(case);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (replay: case_rng({case})): {msg}");
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0u64);
        check_n("trivial", 10, |_rng| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 10);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        check_n("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn case_rng_is_deterministic() {
        let a: Vec<u32> = (0..8).map(|_| case_rng(3).next_u32()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
    }

    #[test]
    fn assert_close_rejects_far() {
        assert!(assert_close(&[1.0], &[2.0], 1e-3).is_err());
    }
}
