//! Steady-state decode makes zero heap allocations — the hot-path pin
//! behind the bench artifact's `decode_allocs_per_step` field.
//!
//! Builds a tiny sparse model through the public pruning pipeline, runs
//! one full generation pass through the arena-backed
//! `SparseModel::forward_cached_scratch` to warm the `StepArena` to the
//! workload's high-water mark (the attention score buffer needs
//! `pos + rows` floats, which grows every decode step, so a single
//! warmed step is not enough — only a full pass is), then repeats the
//! identical workload and asserts, via a counting global allocator, that
//! not a single heap allocation happens inside the steady-state
//! forwards.  Both passes must also reproduce the plain
//! `forward_cached` token trajectory bit-for-bit, so the zero-alloc
//! path can never buy speed with drift.
//!
//! This file holds exactly one `#[test]`: libtest runs tests in the same
//! binary concurrently, and a sibling test's allocations would bleed
//! into the global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use permllm::coordinator::{prune_with_recipe, PipelineCfg};
use permllm::data::{Corpus, CorpusKind};
use permllm::lcp::LcpCfg;
use permllm::model::{synth_trained_params, ModelConfig};
use permllm::pruning::Metric;
use permllm::recipe::PruneRecipe;
use permllm::runtime::{ExecBackend, NativeCfg, NativeEngine};
use permllm::serve::{greedy_token, KvStore, ServePath, SparseModel};
use permllm::sparsity::NmConfig;
use permllm::tensor::Mat;
use permllm::util::scratch::StepArena;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn tiny_sparse_model(nm: NmConfig) -> SparseModel {
    let cfg = ModelConfig::by_name("tiny-s").unwrap();
    let ps = synth_trained_params(&cfg, 11);
    let corpus = Corpus::build(CorpusKind::C4Like, 5);
    let pc = PipelineCfg {
        nm,
        calib_seqs: 2,
        calib_len: 32,
        calib_rows: 32,
        lcp: LcpCfg { block: 16, steps: 4, lr: 0.1, nm, ..Default::default() },
        ..Default::default()
    };
    let pruned = prune_with_recipe(&ps, &corpus, &PruneRecipe::oneshot(Metric::Wanda, nm), &pc);
    SparseModel::from_pruned(&pruned).unwrap()
}

/// Prefill + `gen_steps` greedy decode steps through the arena-backed
/// forward, counting heap allocations around each decode-step forward
/// only (sampling and embedding are the gated scope's exits).  Returns
/// `(allocations inside the forwards, per-prompt tokens)`.
fn scratch_pass(
    sm: &SparseModel,
    engine: &mut dyn ExecBackend,
    prompts: &[Vec<u32>],
    gen_steps: usize,
    arena: &mut StepArena,
) -> (u64, Vec<Vec<u32>>) {
    let r = prompts.len();
    let rows = prompts[0].len();
    let path = ServePath::FullDecoder;
    let mut caches: Vec<KvStore> = (0..r).map(|_| sm.new_cache()).collect();
    for c in &mut caches {
        c.reserve(rows + gen_steps);
    }
    let mut x = Mat::zeros(r * rows, sm.width());
    let mut spans = Vec::with_capacity(r);
    for (i, p) in prompts.iter().enumerate() {
        let e = sm.embed(p).unwrap();
        for rr in 0..rows {
            x.row_mut(i * rows + rr).copy_from_slice(e.row(rr));
        }
        spans.push((i * rows, (i + 1) * rows));
    }
    let h = sm.forward_cached_scratch(engine, &x, &spans, &mut caches, path, arena).unwrap();
    let step_spans: Vec<(usize, usize)> = (0..r).map(|i| (i, i + 1)).collect();
    let mut cur = Mat::zeros(r, sm.width());
    for (i, &(_, hi)) in spans.iter().enumerate() {
        cur.row_mut(i).copy_from_slice(h.row(hi - 1));
    }
    arena.give(h);
    arena.step();
    let mut tokens: Vec<Vec<u32>> = vec![Vec::new(); r];
    let mut xs = Mat::zeros(r, sm.width());
    let mut fwd_allocs = 0u64;
    for _ in 0..gen_steps {
        let logits = sm.logits(&cur);
        for i in 0..r {
            let tok = greedy_token(logits.row(i));
            tokens[i].push(tok);
            xs.row_mut(i).copy_from_slice(sm.embed(&[tok]).unwrap().row(0));
        }
        let a0 = ALLOCS.load(Ordering::Relaxed);
        let h = sm
            .forward_cached_scratch(engine, &xs, &step_spans, &mut caches, path, arena)
            .unwrap();
        fwd_allocs += ALLOCS.load(Ordering::Relaxed) - a0;
        cur.data_mut().copy_from_slice(h.data());
        arena.give(h);
        arena.step();
    }
    (fwd_allocs, tokens)
}

/// The same workload through the allocating `forward_cached` — the
/// trajectory reference the scratch passes must reproduce exactly.
fn reference_pass(
    sm: &SparseModel,
    engine: &mut dyn ExecBackend,
    prompts: &[Vec<u32>],
    gen_steps: usize,
) -> Vec<Vec<u32>> {
    let r = prompts.len();
    let rows = prompts[0].len();
    let path = ServePath::FullDecoder;
    let mut caches: Vec<KvStore> = (0..r).map(|_| sm.new_cache()).collect();
    let mut x = Mat::zeros(r * rows, sm.width());
    let mut spans = Vec::with_capacity(r);
    for (i, p) in prompts.iter().enumerate() {
        let e = sm.embed(p).unwrap();
        for rr in 0..rows {
            x.row_mut(i * rows + rr).copy_from_slice(e.row(rr));
        }
        spans.push((i * rows, (i + 1) * rows));
    }
    let h = sm.forward_cached(engine, &x, &spans, &mut caches, path).unwrap();
    let step_spans: Vec<(usize, usize)> = (0..r).map(|i| (i, i + 1)).collect();
    let mut cur = Mat::zeros(r, sm.width());
    for (i, &(_, hi)) in spans.iter().enumerate() {
        cur.row_mut(i).copy_from_slice(h.row(hi - 1));
    }
    let mut tokens: Vec<Vec<u32>> = vec![Vec::new(); r];
    for _ in 0..gen_steps {
        let logits = sm.logits(&cur);
        let mut xs = Mat::zeros(r, sm.width());
        for i in 0..r {
            let tok = greedy_token(logits.row(i));
            tokens[i].push(tok);
            xs.row_mut(i).copy_from_slice(sm.embed(&[tok]).unwrap().row(0));
        }
        cur = sm.forward_cached(engine, &xs, &step_spans, &mut caches, path).unwrap();
    }
    tokens
}

#[test]
fn steady_state_decode_steps_make_zero_heap_allocations() {
    let sm = tiny_sparse_model(NmConfig::PAT_2_4);
    let mut engine = NativeEngine::new(NativeCfg { threads: 1, ..NativeCfg::default() });
    let vocab = sm.cfg().vocab as u32;
    let (n_prompts, rows, gen_steps) = (3usize, 6usize, 5usize);
    let prompts: Vec<Vec<u32>> = (0..n_prompts)
        .map(|i| (0..rows).map(|r| ((i * 31 + r * 7) as u32) % vocab).collect())
        .collect();

    let want = reference_pass(&sm, &mut engine, &prompts, gen_steps);

    let mut arena = StepArena::new();
    // Pass 1 (warmup): allowed to grow the arena to the workload's
    // high-water mark, must already match the reference trajectory.
    let (_, warm_tokens) = scratch_pass(&sm, &mut engine, &prompts, gen_steps, &mut arena);
    assert_eq!(warm_tokens, want, "warmup scratch pass diverged from forward_cached");
    let warm_grows = arena.grow_events();

    // Pass 2 (measured): identical workload, warmed arena — zero heap
    // allocations inside the decode-step forwards, zero arena growth.
    let (fwd_allocs, tokens) = scratch_pass(&sm, &mut engine, &prompts, gen_steps, &mut arena);
    assert_eq!(tokens, want, "measured scratch pass diverged from forward_cached");
    assert_eq!(arena.grow_events(), warm_grows, "warmed-up arena grew during the measured pass");
    assert_eq!(
        fwd_allocs,
        0,
        "steady-state decode forwards must not touch the heap ({fwd_allocs} allocations \
         across {gen_steps} steps)"
    );
}
