//! Cross-check: pure-Rust LCP gradients vs the `ExecBackend` route.
//!
//! The repo's strongest correctness signal for the paper's core math
//! (DESIGN.md §8): the hand-derived Sinkhorn/STE/cosine backward in
//! `lcp::trainer::HostBackend` must match what the artifact interface
//! serves — loss AND gradient, across temperatures and permutations, and
//! whole training trajectories.
//!
//! * Default build: [`HostBackend`] vs [`ExecLcpBackend`] over the native
//!   engine.  Runs everywhere, no artifacts needed.
//! * `--features pjrt` with artifacts built: the same harness against the
//!   AOT `lcp_grad` XLA graph (which itself runs the L1 Pallas kernels).

use permllm::lcp::{harden, train_lcp, HostBackend, LayerData, LcpBackend, LcpCfg};
use permllm::pruning::{importance, Metric};
use permllm::runtime::{ExecLcpBackend, NativeCfg, NativeEngine};
use permllm::sparsity::NmConfig;
use permllm::tensor::Mat;
use permllm::util::rng::Pcg32;
use permllm::util::testkit::assert_close;

fn layer(seed: u64, c_out: usize, c_in: usize, rows: usize) -> LayerData {
    let mut rng = Pcg32::seeded(seed);
    let w = Mat::randn(c_out, c_in, 0.2, &mut rng);
    let x = Mat::randn(rows, c_in, 1.0, &mut rng);
    let s = importance(Metric::Wanda, &w, &x);
    LayerData::new(w, s, x)
}

#[test]
fn host_and_native_exec_backends_agree_on_loss_and_grad() {
    let (c_out, c_in, rows, b) = (12usize, 32usize, 20usize, 8usize);
    let n_b = c_in / b;
    let nm = NmConfig::PAT_2_4;
    let iters = 5;
    let data = layer(21, c_out, c_in, rows);

    let mut engine = NativeEngine::new(NativeCfg {
        nm,
        sinkhorn_iters: iters,
        ..NativeCfg::default()
    });

    for (case, tau) in [(0u64, 1.0f32), (1, 0.5), (2, 0.15)] {
        let mut case_rng = Pcg32::seeded(100 + case);
        let w_p: Vec<Mat> = (0..n_b).map(|_| Mat::randn(b, b, 0.4, &mut case_rng)).collect();

        let mut host = HostBackend::new(&data, nm, iters);
        let soft_host = host.soft_perms(&w_p, tau);
        let hard: Vec<Vec<usize>> = soft_host.iter().map(harden).collect();
        let (loss_h, grad_h) = host.loss_grad(&w_p, &hard, tau);

        let mut exec = ExecLcpBackend::new(&mut engine, &data, b).unwrap();
        let soft_exec = exec.soft_perms(&w_p, tau);
        for (a, h) in soft_exec.iter().zip(&soft_host) {
            assert_close(a.data(), h.data(), 1e-4).unwrap();
        }
        let (loss_e, grad_e) = exec.loss_grad(&w_p, &hard, tau);

        assert!(
            (loss_h - loss_e).abs() < 1e-4 * loss_h.abs().max(1e-3),
            "tau {tau}: loss host {loss_h} vs exec {loss_e}"
        );
        for (n, (gh, ge)) in grad_h.iter().zip(&grad_e).enumerate() {
            assert_close(gh.data(), ge.data(), 1e-4)
                .unwrap_or_else(|e| panic!("tau {tau} block {n}: {e}"));
        }
    }
}

#[test]
fn native_exec_backend_trains_like_host_backend() {
    let (c_out, c_in, rows) = (16usize, 32usize, 24usize);
    let nm = NmConfig::PAT_2_4;
    let iters = 5;
    let data = layer(33, c_out, c_in, rows);
    let cfg = LcpCfg { block: 8, sinkhorn_iters: iters, steps: 12, lr: 0.05, nm, ..Default::default() };

    let res_host = {
        let mut b = HostBackend::new(&data, nm, iters);
        train_lcp(&mut b, c_in, cfg)
    };
    let res_exec = {
        let mut engine = NativeEngine::new(NativeCfg {
            nm,
            sinkhorn_iters: iters,
            ..NativeCfg::default()
        });
        let mut b = ExecLcpBackend::new(&mut engine, &data, cfg.block).unwrap();
        train_lcp(&mut b, c_in, cfg)
    };
    // Identical math + identical init => identical trajectories.
    assert_eq!(res_host.src_of, res_exec.src_of, "diverged permutations");
    assert!((res_host.best_loss - res_exec.best_loss).abs() < 1e-4);
    assert_eq!(res_host.history.len(), res_exec.history.len());
    for (h, e) in res_host.history.iter().zip(&res_exec.history) {
        assert!((h - e).abs() < 1e-4, "history diverged: {h} vs {e}");
    }
}

/// The same cross-checks against the AOT artifacts (pjrt builds with
/// `make artifacts` run; skips with a notice otherwise).
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use std::path::{Path, PathBuf};

    use permllm::runtime::Engine;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny-m")
    }

    #[test]
    fn host_and_artifact_backends_agree_on_loss_and_grad() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
        let mut engine = Engine::load_lazy(&dir).unwrap();
        let spec = engine
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.kind == "lcp_grad")
            .expect("no lcp_grad artifact")
            .clone();
        let (c_out, c_in) = (spec.attrs["c_out"], spec.attrs["c_in"]);
        let (n_b, b) = (spec.attrs["n_b"], spec.attrs["block"]);
        let rows = spec.inputs.iter().find(|i| i.name == "x").unwrap().shape[0];
        let iters = engine.manifest().sinkhorn_iters;
        let nm = NmConfig { m: engine.manifest().lcp_m, keep: engine.manifest().lcp_keep };

        let mut rng = Pcg32::seeded(21);
        let w = Mat::randn(c_out, c_in, 0.2, &mut rng);
        let x = Mat::randn(rows, c_in, 1.0, &mut rng);
        let s = importance(Metric::Wanda, &w, &x);
        let data = LayerData::new(w, s, x);

        for (case, tau) in [(0u64, 1.0f32), (1, 0.5), (2, 0.15)] {
            let mut case_rng = Pcg32::seeded(100 + case);
            let w_p: Vec<Mat> =
                (0..n_b).map(|_| Mat::randn(b, b, 0.4, &mut case_rng)).collect();

            let mut host = HostBackend::new(&data, nm, iters);
            let soft_host = host.soft_perms(&w_p, tau);
            let hard: Vec<Vec<usize>> = soft_host.iter().map(harden).collect();
            let (loss_h, grad_h) = host.loss_grad(&w_p, &hard, tau);

            let mut art = ExecLcpBackend::new(&mut engine, &data, b).unwrap();
            let soft_art = art.soft_perms(&w_p, tau);
            for (a, h) in soft_art.iter().zip(&soft_host) {
                assert_close(a.data(), h.data(), 5e-4).unwrap();
            }
            let (loss_a, grad_a) = art.loss_grad(&w_p, &hard, tau);

            assert!(
                (loss_h - loss_a).abs() < 5e-4 * loss_h.abs().max(1e-3),
                "tau {tau}: loss host {loss_h} vs artifact {loss_a}"
            );
            for (n, (gh, ga)) in grad_h.iter().zip(&grad_a).enumerate() {
                assert_close(gh.data(), ga.data(), 5e-3)
                    .unwrap_or_else(|e| panic!("tau {tau} block {n}: {e}"));
            }
        }
    }

    #[test]
    fn artifact_backend_trains_like_host_backend() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
        let mut engine = Engine::load_lazy(&dir).unwrap();
        let spec = engine
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.kind == "lcp_grad")
            .unwrap()
            .clone();
        let (c_out, c_in) = (spec.attrs["c_out"], spec.attrs["c_in"]);
        let rows = spec.inputs.iter().find(|i| i.name == "x").unwrap().shape[0];
        let iters = engine.manifest().sinkhorn_iters;
        let nm = NmConfig { m: engine.manifest().lcp_m, keep: engine.manifest().lcp_keep };

        let mut rng = Pcg32::seeded(33);
        let w = Mat::randn(c_out, c_in, 0.2, &mut rng);
        let x = Mat::randn(rows, c_in, 1.0, &mut rng);
        let s = importance(Metric::Wanda, &w, &x);
        let data = LayerData::new(w, s, x);

        let cfg = LcpCfg {
            block: engine.manifest().lcp_block,
            sinkhorn_iters: iters,
            steps: 8,
            lr: 0.05,
            nm,
            ..Default::default()
        };
        let res_host = {
            let mut b = HostBackend::new(&data, nm, iters);
            train_lcp(&mut b, c_in, cfg)
        };
        let res_art = {
            let mut b = ExecLcpBackend::new(&mut engine, &data, cfg.block).unwrap();
            train_lcp(&mut b, c_in, cfg)
        };
        // Identical math + identical init => identical trajectories.
        assert_eq!(res_host.src_of, res_art.src_of, "diverged permutations");
        assert!((res_host.best_loss - res_art.best_loss).abs() < 1e-3);
    }
}
