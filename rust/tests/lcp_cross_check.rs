//! Cross-check: pure-Rust LCP gradients vs the AOT `lcp_grad` artifact.
//!
//! The repo's strongest correctness signal for the paper's core math
//! (DESIGN.md §8): the hand-derived Sinkhorn/STE/cosine backward in
//! `lcp::trainer::HostBackend` must match the JAX `jax.value_and_grad`
//! graph (which itself runs the L1 Pallas kernels) to float tolerance —
//! loss AND gradient, across temperatures and permutations.
//!
//! Skips (with a notice) when artifacts are absent.

use std::path::{Path, PathBuf};

use permllm::lcp::{harden, HostBackend, LayerData, LcpBackend};
use permllm::pruning::{importance, Metric};
use permllm::runtime::{ArtifactBackend, Engine};
use permllm::sparsity::NmConfig;
use permllm::tensor::Mat;
use permllm::util::rng::Pcg32;
use permllm::util::testkit::assert_close;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny-m")
}

#[test]
fn host_and_artifact_backends_agree_on_loss_and_grad() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::load_lazy(&dir).unwrap();
    let spec = engine
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.kind == "lcp_grad")
        .expect("no lcp_grad artifact")
        .clone();
    let (c_out, c_in) = (spec.attrs["c_out"], spec.attrs["c_in"]);
    let (n_b, b) = (spec.attrs["n_b"], spec.attrs["block"]);
    let rows = spec.inputs.iter().find(|i| i.name == "x").unwrap().shape[0];
    let iters = engine.manifest().sinkhorn_iters;
    let nm = NmConfig { m: engine.manifest().lcp_m, keep: engine.manifest().lcp_keep };

    let mut rng = Pcg32::seeded(21);
    let w = Mat::randn(c_out, c_in, 0.2, &mut rng);
    let x = Mat::randn(rows, c_in, 1.0, &mut rng);
    let s = importance(Metric::Wanda, &w, &x);
    let data = LayerData::new(w, s, x);

    for (case, tau) in [(0u64, 1.0f32), (1, 0.5), (2, 0.15)] {
        let mut case_rng = Pcg32::seeded(100 + case);
        let w_p: Vec<Mat> = (0..n_b).map(|_| Mat::randn(b, b, 0.4, &mut case_rng)).collect();

        let mut host = HostBackend::new(&data, nm, iters);
        let soft_host = host.soft_perms(&w_p, tau);
        let hard: Vec<Vec<usize>> = soft_host.iter().map(harden).collect();
        let (loss_h, grad_h) = host.loss_grad(&w_p, &hard, tau);

        let mut art = ArtifactBackend::new(&mut engine, &data).unwrap();
        let soft_art = art.soft_perms(&w_p, tau);
        for (a, h) in soft_art.iter().zip(&soft_host) {
            assert_close(a.data(), h.data(), 5e-4).unwrap();
        }
        let (loss_a, grad_a) = art.loss_grad(&w_p, &hard, tau);

        assert!(
            (loss_h - loss_a).abs() < 5e-4 * loss_h.abs().max(1e-3),
            "tau {tau}: loss host {loss_h} vs artifact {loss_a}"
        );
        for (n, (gh, ga)) in grad_h.iter().zip(&grad_a).enumerate() {
            assert_close(gh.data(), ga.data(), 5e-3)
                .unwrap_or_else(|e| panic!("tau {tau} block {n}: {e}"));
        }
    }
}

#[test]
fn artifact_backend_trains_like_host_backend() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::load_lazy(&dir).unwrap();
    let spec = engine
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.kind == "lcp_grad")
        .unwrap()
        .clone();
    let (c_out, c_in) = (spec.attrs["c_out"], spec.attrs["c_in"]);
    let rows = spec.inputs.iter().find(|i| i.name == "x").unwrap().shape[0];
    let iters = engine.manifest().sinkhorn_iters;
    let nm = NmConfig { m: engine.manifest().lcp_m, keep: engine.manifest().lcp_keep };

    let mut rng = Pcg32::seeded(33);
    let w = Mat::randn(c_out, c_in, 0.2, &mut rng);
    let x = Mat::randn(rows, c_in, 1.0, &mut rng);
    let s = importance(Metric::Wanda, &w, &x);
    let data = LayerData::new(w, s, x);

    let cfg = permllm::lcp::LcpCfg {
        block: engine.manifest().lcp_block,
        sinkhorn_iters: iters,
        steps: 8,
        lr: 0.05,
        nm,
        ..Default::default()
    };
    let res_host = {
        let mut b = HostBackend::new(&data, nm, iters);
        permllm::lcp::train_lcp(&mut b, c_in, cfg)
    };
    let res_art = {
        let mut b = ArtifactBackend::new(&mut engine, &data).unwrap();
        permllm::lcp::train_lcp(&mut b, c_in, cfg)
    };
    // Identical math + identical init => identical trajectories.
    assert_eq!(res_host.src_of, res_art.src_of, "diverged permutations");
    assert!((res_host.best_loss - res_art.best_loss).abs() < 1e-3);
}
