//! Parity: Rust host forward vs the AOT `lm_forward` artifact.
//!
//! The same parameters and tokens must produce (near-)identical logits
//! through the host transformer (rust/src/model/forward.rs) and the JAX
//! graph — this is what makes host-side perplexity evaluation of pruned
//! models trustworthy. Skips when artifacts are absent.

use std::path::{Path, PathBuf};

use permllm::data::{batch_to_i32, sample_batch, Corpus, CorpusKind};
use permllm::model::{synth_trained_params, ParamStore};
use permllm::runtime::{literal_to_vec, tokens_to_literal, vec_to_literal, Engine};
use permllm::util::rng::Pcg32;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny-m")
}

#[test]
fn host_forward_matches_lm_forward_artifact() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::load_lazy(&dir).unwrap();
    let cfg = engine.manifest().config.clone();
    let batch_size = engine.manifest().batch;
    let param_order = engine.manifest().param_order.clone();

    let ps: ParamStore = synth_trained_params(&cfg, 77);
    let corpus = Corpus::build(CorpusKind::C4Like, 1);
    let mut rng = Pcg32::seeded(5);
    let batch = sample_batch(&corpus, &mut rng, batch_size, cfg.seq_len);

    // Artifact path.
    let mut inputs: Vec<xla::Literal> = Vec::new();
    for (name, shape) in &param_order {
        inputs.push(vec_to_literal(ps.get(name).data(), shape).unwrap());
    }
    inputs.push(tokens_to_literal(&batch_to_i32(&batch), batch_size, cfg.seq_len).unwrap());
    let outs = engine.run("lm_forward", &inputs).unwrap();
    let logits_art = literal_to_vec(&outs[0]).unwrap(); // [B, T, V]

    // Host path.
    let logits_host = permllm::model::lm_forward(&ps, &batch);

    let (t, v) = (cfg.seq_len, cfg.vocab);
    let mut max_abs = 0.0f32;
    let mut max_rel = 0.0f32;
    for (bi, l) in logits_host.iter().enumerate() {
        for pos in 0..t {
            let host_row = l.row(pos);
            let art_row = &logits_art[bi * t * v + pos * v..bi * t * v + (pos + 1) * v];
            for (h, a) in host_row.iter().zip(art_row) {
                let d = (h - a).abs();
                max_abs = max_abs.max(d);
                max_rel = max_rel.max(d / h.abs().max(1.0));
            }
        }
    }
    eprintln!("max |host - artifact| = {max_abs:.3e} (rel {max_rel:.3e})");
    assert!(max_rel < 2e-3, "host/artifact logits diverge: abs {max_abs} rel {max_rel}");

    // Argmax agreement at every position (what eval actually consumes).
    for (bi, l) in logits_host.iter().enumerate().take(2) {
        for pos in [0usize, t / 2, t - 1] {
            let host_row = l.row(pos);
            let art_row = &logits_art[bi * t * v + pos * v..bi * t * v + (pos + 1) * v];
            let am_h = argmax(host_row);
            let am_a = argmax(art_row);
            assert_eq!(am_h, am_a, "argmax differs at batch {bi} pos {pos}");
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}
