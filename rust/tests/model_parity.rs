//! Parity: Rust host forward vs the `lm_forward` artifact route.
//!
//! The same parameters and tokens must produce (near-)identical logits
//! through the host transformer (rust/src/model/forward.rs) and the
//! `ExecBackend` serving `lm_forward` — this is what makes backend-routed
//! perplexity evaluation of pruned models trustworthy.
//!
//! * Default build: the native engine (exercises the full param
//!   flatten/rebuild + token plumbing; logits must match bit-for-bit).
//! * `--features pjrt` with artifacts: the AOT JAX graph (tolerance-based;
//!   skips with a notice when artifacts are absent).

use permllm::data::{batch_to_i32, sample_batch, Corpus, CorpusKind};
use permllm::model::{synth_trained_params, ModelConfig};
use permllm::runtime::{ExecBackend, NativeEngine, TensorValue};
use permllm::util::rng::Pcg32;

#[test]
fn host_forward_matches_native_lm_forward_exactly() {
    let cfg = ModelConfig::by_name("tiny-m").unwrap();
    let ps = synth_trained_params(&cfg, 77);
    let corpus = Corpus::build(CorpusKind::C4Like, 1);
    let mut rng = Pcg32::seeded(5);
    let (batch_size, seq_len) = (3usize, 24usize);
    let batch = sample_batch(&corpus, &mut rng, batch_size, seq_len);

    // Backend path: params flattened in canonical order + i32 tokens.
    let mut inputs: Vec<TensorValue> = Vec::new();
    for name in cfg.param_names() {
        inputs.push(
            TensorValue::f32(cfg.param_shape(&name), ps.get(&name).data().to_vec()).unwrap(),
        );
    }
    inputs
        .push(TensorValue::i32(vec![batch_size, seq_len], batch_to_i32(&batch)).unwrap());
    let mut engine = NativeEngine::with_model(cfg.clone());
    let outs = engine.run("lm_forward", &inputs).unwrap();
    assert_eq!(outs[0].shape(), &[batch_size, seq_len, cfg.vocab]);
    let logits_exec = outs[0].as_f32().unwrap();

    // Host path.
    let logits_host = permllm::model::lm_forward(&ps, &batch);

    let (t, v) = (seq_len, cfg.vocab);
    for (bi, l) in logits_host.iter().enumerate() {
        for pos in 0..t {
            let host_row = l.row(pos);
            let exec_row = &logits_exec[bi * t * v + pos * v..bi * t * v + (pos + 1) * v];
            assert_eq!(host_row, exec_row, "batch {bi} pos {pos} diverged");
        }
    }
}

/// With artifacts present: host vs the AOT `lm_forward` XLA graph.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use std::path::{Path, PathBuf};

    use permllm::eval::{eval_perplexity, eval_perplexity_exec};
    use permllm::model::ParamStore;
    use permllm::runtime::Engine;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny-m")
    }

    #[test]
    fn host_forward_matches_lm_forward_artifact() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
        let mut engine = Engine::load_lazy(&dir).unwrap();
        let cfg = engine.manifest().config.clone();
        let batch_size = engine.manifest().batch;

        let ps: ParamStore = synth_trained_params(&cfg, 77);
        let corpus = Corpus::build(CorpusKind::C4Like, 1);
        let mut rng = Pcg32::seeded(5);
        let batch = sample_batch(&corpus, &mut rng, batch_size, cfg.seq_len);

        // Artifact path through the ExecBackend trait.
        let mut inputs: Vec<TensorValue> = Vec::new();
        for name in cfg.param_names() {
            inputs.push(
                TensorValue::f32(cfg.param_shape(&name), ps.get(&name).data().to_vec())
                    .unwrap(),
            );
        }
        inputs.push(
            TensorValue::i32(vec![batch_size, cfg.seq_len], batch_to_i32(&batch)).unwrap(),
        );
        let outs = engine.run("lm_forward", &inputs).unwrap();
        let logits_art = outs[0].as_f32().unwrap(); // [B, T, V]

        // Host path.
        let logits_host = permllm::model::lm_forward(&ps, &batch);

        let (t, v) = (cfg.seq_len, cfg.vocab);
        let mut max_abs = 0.0f32;
        let mut max_rel = 0.0f32;
        for (bi, l) in logits_host.iter().enumerate() {
            for pos in 0..t {
                let host_row = l.row(pos);
                let art_row = &logits_art[bi * t * v + pos * v..bi * t * v + (pos + 1) * v];
                for (h, a) in host_row.iter().zip(art_row) {
                    let d = (h - a).abs();
                    max_abs = max_abs.max(d);
                    max_rel = max_rel.max(d / h.abs().max(1.0));
                }
            }
        }
        eprintln!("max |host - artifact| = {max_abs:.3e} (rel {max_rel:.3e})");
        assert!(max_rel < 2e-3, "host/artifact logits diverge: abs {max_abs} rel {max_rel}");

        // Argmax agreement at sampled positions (what eval consumes).
        for (bi, l) in logits_host.iter().enumerate().take(2) {
            for pos in [0usize, t / 2, t - 1] {
                let host_row = l.row(pos);
                let art_row = &logits_art[bi * t * v + pos * v..bi * t * v + (pos + 1) * v];
                assert_eq!(argmax(host_row), argmax(art_row), "argmax differs at {bi}/{pos}");
            }
        }
    }

    #[test]
    fn artifact_perplexity_matches_host() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
        let mut engine = Engine::load_lazy(&dir).unwrap();
        let cfg = engine.manifest().config.clone();
        let batch = engine.manifest().batch;
        let ps = synth_trained_params(&cfg, 9);
        let corpus = Corpus::build(CorpusKind::WikitextLike, 3);
        let host = eval_perplexity(&ps, &corpus, 42, batch, cfg.seq_len);
        let art = eval_perplexity_exec(&mut engine, &ps, &corpus, 42, batch, cfg.seq_len)
            .unwrap();
        assert!((host - art).abs() / host < 0.02, "{host} vs {art}");
    }

    fn argmax(xs: &[f32]) -> usize {
        xs.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    }
}
