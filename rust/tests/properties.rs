//! Property tests for the core invariants (seeded, offline, no
//! artifacts):
//!
//! * every [`NmMask`] group retains exactly `keep` of `m` entries;
//! * `Compressed::compress -> to_dense` equals `mask ⊙ w` bit-exactly;
//! * Sinkhorn output is doubly stochastic (rows and columns sum to ~1)
//!   for random temperatures;
//! * Hungarian assignment matches brute-force enumeration on all tested
//!   <= 6x6 random cost matrices;
//! * the native `ExecBackend` serves `sinkhorn_soft_*` identically to the
//!   host tape at random shapes.
//!
//! All cases derive from `testkit::case_rng` (PCG32), so any failure
//! message pins the exact replay seed.

use permllm::lcp::{assign_max, SinkhornTape};
use permllm::runtime::{ExecBackend, NativeCfg, NativeEngine, TensorValue};
use permllm::sparsity::{Compressed, NmConfig, NmMask};
use permllm::tensor::Mat;
use permllm::util::testkit::{assert_close, check_n};

#[test]
fn prop_nm_mask_group_counts_exact() {
    check_n("nm-mask-group-counts", 48, |rng| {
        let cfgs = [
            NmConfig::PAT_2_4,
            NmConfig::PAT_4_8,
            NmConfig { m: 4, keep: 1 },
            NmConfig { m: 4, keep: 3 },
            NmConfig { m: 8, keep: 2 },
        ];
        let cfg = cfgs[rng.below_usize(cfgs.len())];
        let rows = 1 + rng.below_usize(10);
        let groups = 1 + rng.below_usize(10);
        let cols = groups * cfg.m;
        let s = Mat::randn(rows, cols, 1.0, rng);
        let mask = NmMask::from_scores(&s, cfg);
        // Count ones in every group explicitly (not via mask.verify, so
        // this test stays meaningful if verify() ever changes).
        for r in 0..rows {
            for g in 0..groups {
                let ones =
                    (0..cfg.m).filter(|&k| mask.get(r, g * cfg.m + k)).count();
                if ones != cfg.keep {
                    return Err(format!(
                        "row {r} group {g}: kept {ones}, want {} (cfg {cfg:?})",
                        cfg.keep
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_compress_to_dense_bit_exact() {
    check_n("compress-bit-exact", 48, |rng| {
        let cfg = if rng.below(2) == 0 { NmConfig::PAT_2_4 } else { NmConfig::PAT_4_8 };
        let c_out = 1 + rng.below_usize(8);
        let c_in = cfg.m * (1 + rng.below_usize(8));
        let w = Mat::randn(c_out, c_in, 1.0, rng);
        let mask = NmMask::from_scores(&w.map(f32::abs), cfg);
        let comp = Compressed::compress(&w, &mask);
        let dense = comp.to_dense();
        let want = mask.apply(&w);
        // Bit-exact: compression stores the retained f32s verbatim.
        if dense.data() != want.data() {
            return Err("compress -> to_dense differs from mask ⊙ w".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sinkhorn_is_doubly_stochastic() {
    check_n("sinkhorn-doubly-stochastic", 32, |rng| {
        let b = 4 + rng.below_usize(13); // 4..=16
        let tau = rng.range_f32(0.3, 2.0);
        let iters = 40;
        let w_p = Mat::randn(b, b, 1.0, rng);
        let p = SinkhornTape::forward(&w_p, tau, iters).output().clone();
        for r in 0..b {
            let rs: f32 = p.row(r).iter().sum();
            if (rs - 1.0).abs() > 5e-3 {
                return Err(format!("row {r} sums to {rs} (b={b}, tau={tau})"));
            }
        }
        for c in 0..b {
            let cs: f32 = p.col(c).iter().sum();
            if (cs - 1.0).abs() > 5e-3 {
                return Err(format!("col {c} sums to {cs} (b={b}, tau={tau})"));
            }
        }
        if p.data().iter().any(|&v| v < 0.0 || !v.is_finite()) {
            return Err("negative or non-finite entry".into());
        }
        Ok(())
    });
}

#[test]
fn prop_hungarian_matches_brute_force_up_to_6() {
    fn brute_force_max(gain: &Mat) -> f64 {
        fn rec(k: usize, perm: &mut Vec<usize>, gain: &Mat, best: &mut f64) {
            if k == 1 {
                let sc: f64 =
                    perm.iter().enumerate().map(|(i, &j)| gain[(i, j)] as f64).sum();
                if sc > *best {
                    *best = sc;
                }
                return;
            }
            for i in 0..k {
                rec(k - 1, perm, gain, best);
                if k % 2 == 0 {
                    perm.swap(i, k - 1);
                } else {
                    perm.swap(0, k - 1);
                }
            }
        }
        let n = gain.rows();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut best = f64::NEG_INFINITY;
        rec(n, &mut perm, gain, &mut best);
        best
    }

    check_n("hungarian-vs-brute-force", 40, |rng| {
        let n = 2 + rng.below_usize(5); // 2..=6
        let gain = Mat::randn(n, n, 1.0, rng);
        let assign = assign_max(&gain);
        let mut seen = vec![false; n];
        for &j in &assign {
            if j >= n || seen[j] {
                return Err("assignment is not a permutation".into());
            }
            seen[j] = true;
        }
        let got: f64 = assign.iter().enumerate().map(|(i, &j)| gain[(i, j)] as f64).sum();
        let want = brute_force_max(&gain);
        if (got - want).abs() > 1e-9 {
            return Err(format!("n={n}: got {got}, optimum {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_native_sinkhorn_artifact_matches_host_tape() {
    check_n("native-sinkhorn-artifact", 16, |rng| {
        let n_b = 1 + rng.below_usize(4);
        let b = 4 + rng.below_usize(9); // 4..=12
        let iters = rng.below_usize(7);
        let tau = rng.range_f32(0.4, 1.5);
        let blocks: Vec<Mat> = (0..n_b).map(|_| Mat::randn(b, b, 0.6, rng)).collect();
        let mut flat = Vec::with_capacity(n_b * b * b);
        for blk in &blocks {
            flat.extend_from_slice(blk.data());
        }
        let mut engine =
            NativeEngine::new(NativeCfg { sinkhorn_iters: iters, ..NativeCfg::default() });
        let outs = engine
            .run(
                &format!("sinkhorn_soft_{n_b}x{b}"),
                &[
                    TensorValue::f32(vec![n_b, b, b], flat).map_err(|e| e.to_string())?,
                    TensorValue::scalar(tau),
                ],
            )
            .map_err(|e| format!("native sinkhorn failed: {e:#}"))?;
        let got = outs[0].as_f32().map_err(|e| e.to_string())?;
        let mut want = Vec::with_capacity(n_b * b * b);
        for blk in &blocks {
            want.extend_from_slice(SinkhornTape::forward(blk, tau, iters).output().data());
        }
        assert_close(got, &want, 1e-6)
    });
}
