//! Failure-injection tests: malformed inputs must produce errors, not
//! panics or silent misbehaviour.  Everything here runs offline; the one
//! PJRT-engine case is feature-gated with the engine itself.

use std::path::{Path, PathBuf};

use permllm::runtime::{ExecBackend, Manifest, NativeEngine, TensorValue};
use permllm::sparsity::NmConfig;
use permllm::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("permllm_robust_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn manifest_missing_dir_mentions_make_artifacts() {
    let err = Manifest::load(Path::new("/nonexistent/permllm")).unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
}

#[test]
fn manifest_invalid_json_is_an_error() {
    let d = tmp_dir("badjson");
    std::fs::write(d.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn manifest_missing_sections_is_an_error() {
    let d = tmp_dir("nosection");
    std::fs::write(d.join("manifest.json"), r#"{"config": {"vocab": 4}}"#).unwrap();
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn native_engine_rejects_unknown_artifacts() {
    let mut engine = NativeEngine::default();
    let err = engine.run("nonexistent", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("unknown artifact"), "{err:#}");
    // Malformed shape suffixes are errors too, not panics.
    assert!(engine.run("sinkhorn_soft_", &[]).is_err());
    assert!(engine.run("lcp_grad_0x0", &[]).is_err());
    assert!(engine.run("sparse_fwd_axb", &[]).is_err());
}

#[test]
fn native_engine_rejects_wrong_arity_and_shape() {
    let mut engine = NativeEngine::default();
    // Wrong arity.
    let err = engine.run("sinkhorn_soft_2x4", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("inputs"), "{err:#}");
    // Wrong element count.
    let bad = [
        TensorValue::f32(vec![5], vec![0.0; 5]).unwrap(),
        TensorValue::scalar(1.0),
    ];
    let err = engine.run("sinkhorn_soft_2x4", &bad).unwrap_err();
    assert!(format!("{err:#}").contains("elements"), "{err:#}");
    // Wrong dtype (i32 where f32 expected).
    let bad_dtype = [
        TensorValue::i32(vec![2, 4, 4], vec![0; 32]).unwrap(),
        TensorValue::scalar(1.0),
    ];
    assert!(engine.run("sinkhorn_soft_2x4", &bad_dtype).is_err());
}

#[test]
fn native_engine_lm_forward_requires_model() {
    let mut engine = NativeEngine::default();
    assert!(!engine.supports("lm_forward"));
    let err = engine.run("lm_forward", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("model"), "{err:#}");
}

#[test]
fn native_engine_sparse_fwd_rejects_bad_indices() {
    use permllm::sparsity::{Compressed, NmMask};
    use permllm::tensor::Mat;
    use permllm::util::rng::Pcg32;

    let mut rng = Pcg32::seeded(1);
    let (c_out, c_in, t) = (4usize, 8usize, 3usize);
    let w = Mat::randn(c_out, c_in, 1.0, &mut rng);
    let mask = NmMask::from_scores(&w.map(f32::abs), NmConfig::PAT_2_4);
    let comp = Compressed::compress(&w, &mask);
    let x = Mat::randn(t, c_in, 1.0, &mut rng);
    let vals = TensorValue::f32(vec![c_out, comp.k()], comp.vals().to_vec()).unwrap();
    let idx: Vec<i32> = comp.idx().iter().map(|&v| v as i32).collect();

    let mut engine = NativeEngine::default();
    let name = format!("sparse_fwd_{c_out}x{c_in}");

    // Out-of-range permutation index.
    let bad_src = TensorValue::i32(vec![c_in], vec![99; c_in]).unwrap();
    let inputs = [
        vals.clone(),
        TensorValue::i32(vec![c_out, comp.k()], idx.clone()).unwrap(),
        TensorValue::from_mat(&x),
        bad_src,
    ];
    assert!(engine.run(&name, &inputs).is_err());

    // In-range but duplicated permutation indices (not a permutation).
    let mut dup: Vec<i32> = (0..c_in as i32).collect();
    dup[1] = 0;
    let inputs = [
        vals.clone(),
        TensorValue::i32(vec![c_out, comp.k()], idx.clone()).unwrap(),
        TensorValue::from_mat(&x),
        TensorValue::i32(vec![c_in], dup).unwrap(),
    ];
    let err = engine.run(&name, &inputs).unwrap_err();
    assert!(format!("{err:#}").contains("duplicate"), "{err:#}");

    // Negative column metadata.
    let mut neg = idx.clone();
    neg[0] = -1;
    let src: Vec<i32> = (0..c_in as i32).collect();
    let inputs = [
        vals,
        TensorValue::i32(vec![c_out, comp.k()], neg).unwrap(),
        TensorValue::from_mat(&x),
        TensorValue::i32(vec![c_in], src).unwrap(),
    ];
    assert!(engine.run(&name, &inputs).is_err());
}

#[test]
fn nm_parse_never_panics_on_garbage() {
    for s in ["", ":", "a:b", "4:2", "0:0", "-1:4", "2:4:8", "999999999999:4", "2: 4 "] {
        let _ = NmConfig::parse(s); // must not panic
    }
    assert_eq!(NmConfig::parse("2:4"), Some(NmConfig::PAT_2_4));
}

#[test]
fn json_parser_survives_fuzzish_inputs() {
    let cases = [
        "", "{", "}", "[", "]", "\"", "{\"a\":}", "[1,,2]", "nul", "tru", "-",
        "1e", "\"\\u12\"", "{\"a\":1}extra", "[\"\\q\"]",
    ];
    for c in cases {
        assert!(Json::parse(c).is_err(), "accepted garbage: {c:?}");
    }
    // Deep nesting parses fine at reasonable depth.
    let deep = format!("{}1{}", "[".repeat(64), "]".repeat(64));
    assert!(Json::parse(&deep).is_ok());
}

#[test]
fn param_store_load_rejects_corrupt_files() {
    let d = tmp_dir("params");
    let p = d.join("bad.bin");
    std::fs::write(&p, b"XXXX-not-a-model").unwrap();
    assert!(permllm::model::ParamStore::load(&p).is_err());
    std::fs::write(&p, b"PL").unwrap(); // truncated magic
    assert!(permllm::model::ParamStore::load(&p).is_err());
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use permllm::runtime::Engine;
    use std::path::Path;

    #[test]
    fn engine_rejects_wrong_input_arity_and_shape() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny-m");
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let mut engine = Engine::load_lazy(&dir).unwrap();
        // Wrong arity.
        let err = match engine.run_literals("lm_forward", &[]) {
            Err(e) => e,
            Ok(_) => panic!("accepted empty inputs"),
        };
        assert!(format!("{err:#}").contains("inputs"), "{err:#}");
        // Unknown artifact.
        assert!(engine.run_literals("nonexistent", &[]).is_err());
        // Wrong element count on the first input.
        let spec = engine.manifest().artifact("lm_forward").unwrap().clone();
        let mut bad: Vec<xla::Literal> = Vec::new();
        for _ in 0..spec.inputs.len() {
            bad.push(xla::Literal::vec1(&[0.0f32]));
        }
        let err = match engine.run_literals("lm_forward", &bad) {
            Err(e) => e,
            Ok(_) => panic!("accepted wrong shapes"),
        };
        assert!(format!("{err:#}").contains("elements"), "{err:#}");
    }
}
