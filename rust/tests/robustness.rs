//! Failure-injection tests: malformed inputs must produce errors, not
//! panics or silent misbehaviour.

use std::path::{Path, PathBuf};

use permllm::runtime::{Engine, Manifest};
use permllm::sparsity::NmConfig;
use permllm::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("permllm_robust_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn manifest_missing_dir_mentions_make_artifacts() {
    let err = Manifest::load(Path::new("/nonexistent/permllm")).unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
}

#[test]
fn manifest_invalid_json_is_an_error() {
    let d = tmp_dir("badjson");
    std::fs::write(d.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn manifest_missing_sections_is_an_error() {
    let d = tmp_dir("nosection");
    std::fs::write(d.join("manifest.json"), r#"{"config": {"vocab": 4}}"#).unwrap();
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn engine_rejects_wrong_input_arity_and_shape() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny-m");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut engine = Engine::load_lazy(&dir).unwrap();
    // Wrong arity.
    let err = match engine.run("lm_forward", &[]) {
        Err(e) => e,
        Ok(_) => panic!("accepted empty inputs"),
    };
    assert!(format!("{err:#}").contains("inputs"), "{err:#}");
    // Unknown artifact.
    assert!(engine.run("nonexistent", &[]).is_err());
    // Wrong element count on the first input.
    let spec = engine.manifest().artifact("lm_forward").unwrap().clone();
    let mut bad: Vec<xla::Literal> = Vec::new();
    for _ in 0..spec.inputs.len() {
        bad.push(xla::Literal::vec1(&[0.0f32]));
    }
    let err = match engine.run("lm_forward", &bad) {
        Err(e) => e,
        Ok(_) => panic!("accepted wrong shapes"),
    };
    assert!(format!("{err:#}").contains("elements"), "{err:#}");
}

#[test]
fn nm_parse_never_panics_on_garbage() {
    for s in ["", ":", "a:b", "4:2", "0:0", "-1:4", "2:4:8", "999999999999:4", "2: 4 "] {
        let _ = NmConfig::parse(s); // must not panic
    }
    assert_eq!(NmConfig::parse("2:4"), Some(NmConfig::PAT_2_4));
}

#[test]
fn json_parser_survives_fuzzish_inputs() {
    let cases = [
        "", "{", "}", "[", "]", "\"", "{\"a\":}", "[1,,2]", "nul", "tru", "-",
        "1e", "\"\\u12\"", "{\"a\":1}extra", "[\"\\q\"]",
    ];
    for c in cases {
        assert!(Json::parse(c).is_err(), "accepted garbage: {c:?}");
    }
    // Deep nesting parses fine at reasonable depth.
    let deep = format!("{}1{}", "[".repeat(64), "]".repeat(64));
    assert!(Json::parse(&deep).is_ok());
}

#[test]
fn param_store_load_rejects_corrupt_files() {
    let d = tmp_dir("params");
    let p = d.join("bad.bin");
    std::fs::write(&p, b"XXXX-not-a-model").unwrap();
    assert!(permllm::model::ParamStore::load(&p).is_err());
    std::fs::write(&p, b"PL").unwrap(); // truncated magic
    assert!(permllm::model::ParamStore::load(&p).is_err());
}
