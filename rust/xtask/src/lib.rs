//! Concurrency-invariant analyzer for the PermLLM tree.
//!
//! `cargo run -p xtask -- analyze` lexes every `.rs` file under `rust/src`
//! and `rust/tests` (comment- and string-aware, so rules never fire inside
//! literals) and enforces the named rules documented in
//! `docs/CONCURRENCY.md`:
//!
//! - **AL-01** every `unsafe` block carries an immediately preceding
//!   `// SAFETY:` comment;
//! - **AL-02** no `unwrap`/`expect`/`panic!`/`todo!` in non-test code under
//!   `serve/`, `model/`, `runtime/`, `snapshot/`;
//! - **AL-03** no allocation-capable calls inside `*_scratch` hot-path
//!   functions;
//! - **AL-04** every `Ordering::` site appears in the CONCURRENCY.md atomics
//!   table (drift in either direction fails);
//! - **AL-05** nested `.lock()` acquisitions respect the declared lock
//!   partial order;
//! - **AL-06** every `Condvar` wait sits inside a loop.
//!
//! Suppressions live in `analyze.allow.toml`; unused entries and entries
//! without a justification are themselves findings.

#![forbid(unsafe_code)]

use std::collections::{BTreeMap, HashMap, HashSet};
use std::ffi::OsStr;
use std::fs;
use std::path::Path;

const ORDS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];
const AL02_DIRS: [&str; 4] = [
    "rust/src/serve/",
    "rust/src/model/",
    "rust/src/runtime/",
    "rust/src/snapshot/",
];
const AL02_DOT: [&str; 2] = ["unwrap", "expect"];
const AL02_MACRO: [&str; 3] = ["panic", "todo", "unimplemented"];
const AL03_DOT: [&str; 5] = ["to_vec", "collect", "clone", "to_owned", "to_string"];
const AL03_MACRO: [&str; 2] = ["vec", "format"];
const AL03_PATH: [(&str, &str); 9] = [
    ("Vec", "new"),
    ("Vec", "with_capacity"),
    ("Vec", "from"),
    ("String", "new"),
    ("String", "with_capacity"),
    ("String", "from"),
    ("Box", "new"),
    ("Mat", "zeros"),
    ("Mat", "uninit_filled"),
    ("Mat", "randn"),
];
const WAITS: [&str; 4] = ["wait", "wait_timeout", "wait_while", "wait_timeout_while"];
const ITEM_KWS: [&str; 7] = ["pub", "crate", "mod", "fn", "use", "struct", "impl"];
const CHAIN_PUNCT: [&str; 5] = [".", "]", "[", ")", "("];

const MSG_AL01: &str = "`unsafe` block without an immediately preceding `// SAFETY:` comment";
const MSG_NO_CALL: &str = "could not attribute `Ordering::` site to an atomic call";
const MSG_AL05_RECV: &str = "could not resolve `.lock()` receiver";

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Ident,
    Punct,
    Num,
    Comment,
    Str,
    Char,
    Lifetime,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
    pub snippet: String,
}

#[derive(Clone, Debug)]
pub struct AtomicSite {
    pub field: String,
    pub op: String,
    pub ordering: String,
    pub line: usize,
}

#[derive(Clone, Debug)]
pub struct AtomicRow {
    pub file: String,
    pub field: String,
    pub op: String,
    pub ordering: String,
    pub rationale: String,
    pub line: usize,
}

#[derive(Default)]
pub struct Docs {
    pub lock_ranks: HashMap<String, i64>,
    pub atomics: Vec<AtomicRow>,
}

#[derive(Default)]
pub struct AllowEntry {
    pub rule: String,
    pub file: String,
    pub pattern: String,
    pub reason: String,
    pub used: usize,
}

fn msg_al02_dot(name: &str) -> String {
    format!("`.{name}()` in non-test serving/model/runtime/snapshot code")
}

fn msg_al02_macro(name: &str) -> String {
    format!("`{name}!` in non-test serving/model/runtime/snapshot code")
}

fn msg_al03_dot(name: &str, f: &str) -> String {
    format!("allocation-capable `.{name}()` inside hot-path fn `{f}`")
}

fn msg_al03_macro(name: &str, f: &str) -> String {
    format!("allocation-capable `{name}!` inside hot-path fn `{f}`")
}

fn msg_al03_path(name: &str, tail: &str, f: &str) -> String {
    format!("allocation-capable `{name}::{tail}` inside hot-path fn `{f}`")
}

fn msg_al04_outside(op: &str) -> String {
    format!("`Ordering::` used outside a method call (`{op}`)")
}

fn msg_al04_recv(op: &str) -> String {
    format!("could not resolve atomic receiver for `.{op}(...)`")
}

fn msg_al04_missing(field: &str, op: &str, ord: &str) -> String {
    let tail = "missing from docs/CONCURRENCY.md atomics table";
    format!("atomic site `{field}.{op}` with `Ordering::{ord}` {tail}")
}

fn msg_al04_stale(r: &AtomicRow) -> String {
    let site = format!("`{}.{}` site with `Ordering::{}`", r.field, r.op, r.ordering);
    format!("stale atomics-table row: no {site} in `{}`", r.file)
}

fn msg_al05_undeclared(key: &str) -> String {
    format!("lock `{key}` is not declared in docs/CONCURRENCY.md lock order")
}

fn msg_al05_order(key: &str, rank: i64, hkey: &str, hrank: i64) -> String {
    format!("lock `{key}` (rank {rank}) acquired while holding `{hkey}` (rank {hrank})")
}

fn msg_al06(name: &str) -> String {
    format!("`Condvar::{name}` outside a while-predicate loop (spurious wakeup hazard)")
}

fn msg_allow_unused(a: &AllowEntry) -> String {
    format!("unused allowlist entry: {} {} {}", a.rule, a.file, a.pattern)
}

fn msg_allow_no_reason(a: &AllowEntry) -> String {
    format!("entry for {} has no justification", a.file)
}

fn starts_with(b: &[char], i: usize, pat: &str) -> bool {
    let mut j = i;
    for c in pat.chars() {
        if j >= b.len() || b[j] != c {
            return false;
        }
        j += 1;
    }
    true
}

/// End index (exclusive) of a raw string literal starting at `i`, if any.
fn raw_string_end(b: &[char], i: usize) -> Option<usize> {
    let n = b.len();
    let r = if b[i] == 'b' { i + 1 } else { i };
    if r >= n || b[r] != 'r' {
        return None;
    }
    let mut h = r + 1;
    while h < n && b[h] == '#' {
        h += 1;
    }
    if h >= n || b[h] != '"' {
        return None;
    }
    let hashes = h - (r + 1);
    let mut j = h + 1;
    while j < n {
        if b[j] == '"' && starts_with(b, j + 1, &"#".repeat(hashes)) {
            return Some(j + 1 + hashes);
        }
        j += 1;
    }
    Some(n)
}

/// Comment/string-aware lexer. Literal tokens carry empty text so rule
/// matching can never fire on string contents.
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        if starts_with(&b, i, "//") {
            let mut j = i;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            let text: String = b[i..j].iter().collect();
            toks.push(Tok { kind: Kind::Comment, text, line });
            i = j;
            continue;
        }
        if starts_with(&b, i, "/*") {
            let start = line;
            let mut depth = 1i64;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if starts_with(&b, j, "/*") {
                    depth += 1;
                    j += 2;
                } else if starts_with(&b, j, "*/") {
                    depth -= 1;
                    j += 2;
                } else {
                    if b[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            let text: String = b[i..j.min(n)].iter().collect();
            toks.push(Tok { kind: Kind::Comment, text, line: start });
            i = j;
            continue;
        }
        if c == 'r' || c == 'b' {
            if let Some(j) = raw_string_end(&b, i) {
                let mut m = i;
                while m < j.min(n) {
                    if b[m] == '\n' {
                        line += 1;
                    }
                    m += 1;
                }
                toks.push(Tok { kind: Kind::Str, text: String::new(), line });
                i = j;
                continue;
            }
        }
        if c == '"' || (c == 'b' && i + 1 < n && b[i + 1] == '"') {
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            while j < n {
                if b[j] == '\\' {
                    if j + 1 < n && b[j + 1] == '\n' {
                        line += 1;
                    }
                    j += 2;
                    continue;
                }
                if b[j] == '"' {
                    j += 1;
                    break;
                }
                if b[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            toks.push(Tok { kind: Kind::Str, text: String::new(), line });
            i = j;
            continue;
        }
        if c == '\'' {
            let lifetime = i + 1 < n
                && (b[i + 1].is_alphabetic() || b[i + 1] == '_')
                && (i + 2 >= n || b[i + 2] != '\'');
            if lifetime {
                let mut j = i + 1;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                let text: String = b[i..j].iter().collect();
                toks.push(Tok { kind: Kind::Lifetime, text, line });
                i = j;
                continue;
            }
            let mut j = i + 1;
            while j < n {
                if b[j] == '\\' {
                    if j + 1 < n && b[j + 1] == '\n' {
                        line += 1;
                    }
                    j += 2;
                    continue;
                }
                if b[j] == '\'' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            toks.push(Tok { kind: Kind::Char, text: String::new(), line });
            i = j;
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            let text: String = b[i..j].iter().collect();
            toks.push(Tok { kind: Kind::Ident, text, line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n {
                let ch = b[j];
                if ch.is_alphanumeric() || ch == '_' {
                    j += 1;
                } else if ch == '.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                    j += 1;
                } else if (ch == '+' || ch == '-') && (b[j - 1] == 'e' || b[j - 1] == 'E') {
                    j += 1;
                } else {
                    break;
                }
            }
            let text: String = b[i..j].iter().collect();
            toks.push(Tok { kind: Kind::Num, text, line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    toks
}

fn is_punct(t: &Tok, ch: &str) -> bool {
    t.kind == Kind::Punct && t.text == ch
}

/// Indexes of non-comment tokens.
pub fn sig(toks: &[Tok]) -> Vec<usize> {
    let mut out = Vec::new();
    for (k, t) in toks.iter().enumerate() {
        if t.kind != Kind::Comment {
            out.push(k);
        }
    }
    out
}

/// Token index of the `}` matching the `{` at `open_idx`.
pub fn brace_match(toks: &[Tok], open_idx: usize) -> usize {
    let mut depth = 0i64;
    let mut k = open_idx;
    while k < toks.len() {
        if is_punct(&toks[k], "{") {
            depth += 1;
        } else if is_punct(&toks[k], "}") {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
        k += 1;
    }
    toks.len().saturating_sub(1)
}

/// `(start_tok, end_tok)` spans of `#[cfg(test)]` items.
pub fn test_regions(toks: &[Tok], whole_file_is_test: bool) -> Vec<(usize, usize)> {
    if whole_file_is_test {
        return vec![(0, toks.len().saturating_sub(1))];
    }
    let s = sig(toks);
    let mut regs = Vec::new();
    let mut si = 0usize;
    while si + 6 < s.len() {
        let texts: Vec<&str> = (0..7).map(|d| toks[s[si + d]].text.as_str()).collect();
        let is_cfg_test = texts == ["#", "[", "cfg", "(", "test", ")", "]"]
            && toks[s[si]].kind == Kind::Punct
            && toks[s[si + 2]].kind == Kind::Ident;
        if !is_cfg_test {
            si += 1;
            continue;
        }
        // Skip any further attributes and modifiers to reach the item start.
        let mut k = si + 7;
        while k < s.len() {
            let t = &toks[s[k]];
            if is_punct(t, "#") && k + 1 < s.len() && is_punct(&toks[s[k + 1]], "[") {
                let mut depth = 0i64;
                let mut m = k + 1;
                while m < s.len() {
                    let tt = toks[s[m]].text.as_str();
                    if tt == "[" {
                        depth += 1;
                    } else if tt == "]" {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    m += 1;
                }
                k = m + 1;
                continue;
            }
            let item = t.kind == Kind::Ident && ITEM_KWS.contains(&t.text.as_str());
            if item {
                break;
            }
            k += 1;
        }
        // The region runs to the matching `}` (or `;` for extern items).
        let mut m = k;
        while m < s.len() {
            if is_punct(&toks[s[m]], "{") {
                regs.push((s[si], brace_match(toks, s[m])));
                break;
            }
            if is_punct(&toks[s[m]], ";") {
                regs.push((s[si], s[m]));
                break;
            }
            m += 1;
        }
        si += 1;
    }
    regs
}

fn in_regions(regs: &[(usize, usize)], idx: usize) -> bool {
    regs.iter().any(|&(a, b)| (a..=b).contains(&idx))
}

pub struct FnInfo {
    pub name: String,
    pub body_open: usize,
    pub body_close: usize,
}

/// Every `fn` item with a body, by token span (braces included).
pub fn functions(toks: &[Tok]) -> Vec<FnInfo> {
    let s = sig(toks);
    let mut out = Vec::new();
    let mut si = 0usize;
    while si + 1 < s.len() {
        let t = &toks[s[si]];
        if t.kind == Kind::Ident && t.text == "fn" && toks[s[si + 1]].kind == Kind::Ident {
            let name = toks[s[si + 1]].text.clone();
            let mut depth = 0i64;
            let mut m = si + 2;
            let mut body: Option<usize> = None;
            while m < s.len() {
                let tt = &toks[s[m]];
                if tt.kind == Kind::Punct {
                    match tt.text.as_str() {
                        "(" => depth += 1,
                        ")" => depth -= 1,
                        "{" if depth == 0 => {
                            body = Some(s[m]);
                            break;
                        }
                        ";" if depth == 0 => break,
                        _ => {}
                    }
                }
                m += 1;
            }
            if let Some(b0) = body {
                let body_close = brace_match(toks, b0);
                out.push(FnInfo { name, body_open: b0, body_close });
            }
        }
        si += 1;
    }
    out
}

/// Walk backwards from sig-index `si` to the `(` of the enclosing call.
fn find_call_open(toks: &[Tok], s: &[usize], si: usize) -> Option<usize> {
    let mut depth = 0i64;
    let mut k = si;
    while k > 0 {
        k -= 1;
        if si - k >= 600 {
            break;
        }
        let t = &toks[s[k]];
        if is_punct(t, ")") {
            depth += 1;
        } else if is_punct(t, "(") {
            if depth == 0 {
                return Some(k);
            }
            depth -= 1;
        }
    }
    None
}

/// Field identifier of the receiver ending at sig-index `k` (the token just
/// before the `.method(` being resolved). Handles `]`/`)` suffixes by
/// matching brackets backwards.
fn receiver_field(toks: &[Tok], s: &[usize], k: usize) -> Option<String> {
    let t = &toks[s[k]];
    if t.kind == Kind::Ident || t.kind == Kind::Num {
        return Some(t.text.clone());
    }
    if t.kind == Kind::Punct && (t.text == "]" || t.text == ")") {
        let close = t.text.clone();
        let open = if close == "]" { "[" } else { "(" };
        let mut depth = 0i64;
        let mut m = k + 1;
        while m > 0 {
            m -= 1;
            let tt = &toks[s[m]];
            if tt.kind == Kind::Punct && tt.text == close {
                depth += 1;
            } else if tt.kind == Kind::Punct && tt.text == open {
                depth -= 1;
                if depth == 0 {
                    if m >= 1 && toks[s[m - 1]].kind == Kind::Ident {
                        return Some(toks[s[m - 1]].text.clone());
                    }
                    return None;
                }
            }
        }
    }
    None
}

fn fnd(
    findings: &mut Vec<Finding>,
    lines: &[&str],
    rule: &'static str,
    file: &str,
    line: usize,
    message: String,
) {
    let snippet = if (1..=lines.len()).contains(&line) {
        lines[line - 1].trim().to_string()
    } else {
        String::new()
    };
    findings.push(Finding { rule, file: file.to_string(), line, message, snippet });
}

fn plain(rule: &'static str, file: &str, line: usize, message: String) -> Finding {
    let file = file.to_string();
    Finding { rule, file, line, message, snippet: String::new() }
}

/// Run all six rules over one file. Returns findings plus the resolved
/// atomic-ordering sites (for the AL-04 drift check in [`run`]).
pub fn analyze_file(relpath: &str, src: &str, docs: &Docs) -> (Vec<Finding>, Vec<AtomicSite>) {
    let toks = lex(src);
    let lines: Vec<&str> = src.split('\n').collect();
    let s = sig(&toks);
    let mut pos_of: HashMap<usize, usize> = HashMap::new();
    for (k, &idx) in s.iter().enumerate() {
        pos_of.insert(idx, k);
    }
    let is_test_file = relpath.starts_with("rust/tests/");
    let regs = test_regions(&toks, is_test_file);
    let fns = functions(&toks);
    let mut findings: Vec<Finding> = Vec::new();
    let mut atomics: Vec<AtomicSite> = Vec::new();

    // AL-01: map comment text per line, then walk up from each unsafe block.
    let mut comment_lines: HashMap<usize, Vec<&str>> = HashMap::new();
    let mut code_lines: HashSet<usize> = HashSet::new();
    for t in &toks {
        if t.kind == Kind::Comment {
            let span = t.text.matches('\n').count();
            for l in t.line..=t.line + span {
                comment_lines.entry(l).or_default().push(t.text.as_str());
            }
        } else {
            code_lines.insert(t.line);
        }
    }
    let mut k = 0usize;
    while k + 1 < s.len() {
        let t = &toks[s[k]];
        if t.kind == Kind::Ident && t.text == "unsafe" && is_punct(&toks[s[k + 1]], "{") {
            let mut ok = false;
            let mut l = t.line.saturating_sub(1);
            while l > 0 && comment_lines.contains_key(&l) && !code_lines.contains(&l) {
                if comment_lines[&l].iter().any(|c| c.contains("SAFETY:")) {
                    ok = true;
                    break;
                }
                l -= 1;
            }
            if !ok {
                fnd(&mut findings, &lines, "AL-01", relpath, t.line, MSG_AL01.to_string());
            }
        }
        k += 1;
    }

    // AL-02: panic-capable calls in non-test gated code.
    if AL02_DIRS.iter().any(|d| relpath.starts_with(d)) {
        for (k, &idx) in s.iter().enumerate() {
            if in_regions(&regs, idx) {
                continue;
            }
            let t = &toks[idx];
            if t.kind != Kind::Ident {
                continue;
            }
            let name = t.text.as_str();
            if AL02_DOT.contains(&name) && k > 0 && is_punct(&toks[s[k - 1]], ".") {
                fnd(&mut findings, &lines, "AL-02", relpath, t.line, msg_al02_dot(name));
            }
            if AL02_MACRO.contains(&name) && k + 1 < s.len() && is_punct(&toks[s[k + 1]], "!") {
                fnd(&mut findings, &lines, "AL-02", relpath, t.line, msg_al02_macro(name));
            }
        }
    }

    // AL-03: allocation-capable calls inside `*_scratch` hot-path functions.
    for f in &fns {
        if !f.name.ends_with("_scratch") {
            continue;
        }
        let fname = f.name.as_str();
        let k0 = pos_of[&f.body_open];
        let k1 = pos_of[&f.body_close];
        let mut k = k0;
        while k <= k1 {
            let t = &toks[s[k]];
            if t.kind == Kind::Ident {
                let name = t.text.as_str();
                let bang = k + 1 < s.len() && is_punct(&toks[s[k + 1]], "!");
                if AL03_DOT.contains(&name) && k > 0 && is_punct(&toks[s[k - 1]], ".") {
                    let msg = msg_al03_dot(name, fname);
                    fnd(&mut findings, &lines, "AL-03", relpath, t.line, msg);
                }
                if AL03_MACRO.contains(&name) && bang {
                    let msg = msg_al03_macro(name, fname);
                    fnd(&mut findings, &lines, "AL-03", relpath, t.line, msg);
                }
                let path_call = k + 3 < s.len()
                    && is_punct(&toks[s[k + 1]], ":")
                    && is_punct(&toks[s[k + 2]], ":")
                    && toks[s[k + 3]].kind == Kind::Ident
                    && AL03_PATH.contains(&(name, toks[s[k + 3]].text.as_str()));
                if path_call {
                    let tail = toks[s[k + 3]].text.as_str();
                    let msg = msg_al03_path(name, tail, fname);
                    fnd(&mut findings, &lines, "AL-03", relpath, t.line, msg);
                }
            }
            k += 1;
        }
    }

    // AL-04: resolve every `Ordering::` use to its enclosing atomic call.
    if relpath.starts_with("rust/src/") {
        let mut by_call: BTreeMap<usize, Vec<(usize, String, usize)>> = BTreeMap::new();
        let mut k = 0usize;
        while k + 3 < s.len() {
            let idx = s[k];
            let t = &toks[idx];
            let is_site = !in_regions(&regs, idx)
                && t.kind == Kind::Ident
                && t.text == "Ordering"
                && is_punct(&toks[s[k + 1]], ":")
                && is_punct(&toks[s[k + 2]], ":")
                && toks[s[k + 3]].kind == Kind::Ident
                && ORDS.contains(&toks[s[k + 3]].text.as_str());
            if is_site {
                match find_call_open(&toks, &s, k) {
                    Some(opn) => {
                        let ord = toks[s[k + 3]].text.clone();
                        by_call.entry(opn).or_default().push((k, ord, t.line));
                    }
                    None => {
                        let msg = MSG_NO_CALL.to_string();
                        fnd(&mut findings, &lines, "AL-04", relpath, t.line, msg);
                    }
                }
            }
            k += 1;
        }
        for (opn, sites) in &by_call {
            let opn = *opn;
            let line = sites[0].2;
            if opn < 3 || toks[s[opn - 1]].kind != Kind::Ident {
                let msg = MSG_NO_CALL.to_string();
                fnd(&mut findings, &lines, "AL-04", relpath, line, msg);
                continue;
            }
            let op = toks[s[opn - 1]].text.clone();
            if !is_punct(&toks[s[opn - 2]], ".") {
                fnd(&mut findings, &lines, "AL-04", relpath, line, msg_al04_outside(&op));
                continue;
            }
            match receiver_field(&toks, &s, opn - 3) {
                Some(field) => {
                    let ords: Vec<&str> = sites.iter().map(|(_, o, _)| o.as_str()).collect();
                    let ordering = ords.join("/");
                    atomics.push(AtomicSite { field, op, ordering, line });
                }
                None => {
                    fnd(&mut findings, &lines, "AL-04", relpath, line, msg_al04_recv(&op));
                }
            }
        }
    }

    // AL-05: per-function nested `.lock()` acquisitions against the declared
    // partial order.
    if relpath.starts_with("rust/src/") {
        for f in &fns {
            let k0 = pos_of[&f.body_open];
            let k1 = pos_of[&f.body_close];
            // (acq_tok_idx, lock field, line, release_tok_idx)
            let mut acqs: Vec<(usize, String, usize, usize)> = Vec::new();
            let mut k = k0;
            while k <= k1 {
                let idx = s[k];
                if in_regions(&regs, idx) {
                    k += 1;
                    continue;
                }
                let t = &toks[idx];
                let is_lock = t.kind == Kind::Ident
                    && t.text == "lock"
                    && k > 0
                    && is_punct(&toks[s[k - 1]], ".")
                    && k + 1 <= k1
                    && is_punct(&toks[s[k + 1]], "(");
                if !is_lock {
                    k += 1;
                    continue;
                }
                let line = t.line;
                let field = match receiver_field(&toks, &s, k - 2) {
                    Some(fld) => fld,
                    None => {
                        let msg = MSG_AL05_RECV.to_string();
                        fnd(&mut findings, &lines, "AL-05", relpath, line, msg);
                        k += 1;
                        continue;
                    }
                };
                // Is the guard let-bound? Walk back over the receiver chain
                // to a `=`, then back over the pattern to `let`.
                let k0i = k0 as i64;
                let mut guard: Option<String> = None;
                let mut m = k as i64 - 2;
                while m >= k0i {
                    let tt = &toks[s[m as usize]];
                    let chainy = tt.kind == Kind::Ident
                        || tt.kind == Kind::Num
                        || (tt.kind == Kind::Punct && CHAIN_PUNCT.contains(&tt.text.as_str()));
                    if !chainy {
                        break;
                    }
                    m -= 1;
                }
                if m >= k0i && is_punct(&toks[s[m as usize]], "=") {
                    let mut mm = m - 1;
                    let mut pat: Vec<String> = Vec::new();
                    while mm >= k0i {
                        let tt = &toks[s[mm as usize]];
                        if tt.kind == Kind::Ident && tt.text == "let" {
                            break;
                        }
                        if tt.kind == Kind::Ident {
                            pat.push(tt.text.clone());
                        }
                        mm -= 1;
                    }
                    if mm >= k0i && toks[s[mm as usize]].text == "let" {
                        let names: Vec<&String> = pat.iter().filter(|p| *p != "mut").collect();
                        guard = names.last().map(|g| (*g).clone());
                    }
                }
                let release = match &guard {
                    Some(g) => {
                        // Held to the innermost enclosing block close, or an
                        // explicit drop(guard).
                        let mut depth = 0i64;
                        let mut rel = f.body_close;
                        let mut m2 = k;
                        while m2 <= k1 {
                            let tt = &toks[s[m2]];
                            if is_punct(tt, "{") {
                                depth += 1;
                            } else if is_punct(tt, "}") {
                                if depth == 0 {
                                    rel = s[m2];
                                    break;
                                }
                                depth -= 1;
                            } else if tt.kind == Kind::Ident
                                && tt.text == "drop"
                                && m2 + 2 <= k1
                                && is_punct(&toks[s[m2 + 1]], "(")
                                && toks[s[m2 + 2]].text == *g
                            {
                                rel = s[m2];
                                break;
                            }
                            m2 += 1;
                        }
                        rel
                    }
                    None => {
                        // Temporary guard: held to the end of the statement.
                        let mut depth = 0i64;
                        let mut rel = s[k1];
                        let mut m2 = k;
                        while m2 <= k1 {
                            let tt = &toks[s[m2]];
                            if tt.kind == Kind::Punct {
                                match tt.text.as_str() {
                                    "(" | "[" | "{" => depth += 1,
                                    ")" | "]" | "}" => depth -= 1,
                                    ";" if depth <= 0 => {
                                        rel = s[m2];
                                        break;
                                    }
                                    _ => {}
                                }
                            }
                            m2 += 1;
                        }
                        rel
                    }
                };
                acqs.push((idx, field, line, release));
                k += 1;
            }
            let mut held: Vec<(String, i64, usize)> = Vec::new();
            for (idx, field, line, release) in acqs {
                held.retain(|h| h.2 > idx);
                let key = format!("{relpath}:{field}");
                let rank = match docs.lock_ranks.get(&key) {
                    Some(&r) => r,
                    None => {
                        let msg = msg_al05_undeclared(&key);
                        fnd(&mut findings, &lines, "AL-05", relpath, line, msg);
                        continue;
                    }
                };
                for h in &held {
                    if rank <= h.1 {
                        let msg = msg_al05_order(&key, rank, &h.0, h.1);
                        fnd(&mut findings, &lines, "AL-05", relpath, line, msg);
                    }
                }
                held.push((key, rank, release));
            }
        }
    }

    // AL-06: condvar waits must sit inside a loop (spurious wakeups).
    let mut loops: Vec<(usize, usize)> = Vec::new();
    for (k, &idx) in s.iter().enumerate() {
        let t = &toks[idx];
        if t.kind == Kind::Ident && matches!(t.text.as_str(), "loop" | "while" | "for") {
            let mut depth = 0i64;
            let mut m = k + 1;
            let mut body: Option<usize> = None;
            while m < s.len() {
                let tt = &toks[s[m]];
                if tt.kind == Kind::Punct {
                    match tt.text.as_str() {
                        "(" => depth += 1,
                        ")" => depth -= 1,
                        "{" if depth == 0 => {
                            body = Some(s[m]);
                            break;
                        }
                        ";" if depth == 0 => break,
                        _ => {}
                    }
                }
                m += 1;
            }
            if let Some(b0) = body {
                loops.push((b0, brace_match(&toks, b0)));
            }
        }
    }
    let mut k = 1usize;
    while k + 1 < s.len() {
        let t = &toks[s[k]];
        let is_wait = t.kind == Kind::Ident
            && WAITS.contains(&t.text.as_str())
            && is_punct(&toks[s[k - 1]], ".")
            && is_punct(&toks[s[k + 1]], "(");
        if is_wait {
            // Zero-arg `.wait()` is `Ticket::wait`, not a condvar.
            let zero_arg = t.text == "wait" && k + 2 < s.len() && is_punct(&toks[s[k + 2]], ")");
            let idx = s[k];
            if !zero_arg && !loops.iter().any(|&(a, b)| (a..=b).contains(&idx)) {
                let msg = msg_al06(t.text.as_str());
                fnd(&mut findings, &lines, "AL-06", relpath, t.line, msg);
            }
        }
        k += 1;
    }

    (findings, atomics)
}

/// Parse the machine-checked sections of docs/CONCURRENCY.md.
pub fn parse_docs(path: &Path) -> Docs {
    let mut docs = Docs::default();
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => return docs,
    };
    let mut section = 0u8; // 0 = none, 1 = locks, 2 = atomics
    for (i, raw) in text.split('\n').enumerate() {
        let lineno = i + 1;
        let t = raw.trim();
        match t {
            "<!-- AL05:locks:begin -->" => {
                section = 1;
                continue;
            }
            "<!-- AL04:atomics:begin -->" => {
                section = 2;
                continue;
            }
            "<!-- AL05:locks:end -->" | "<!-- AL04:atomics:end -->" => {
                section = 0;
                continue;
            }
            _ => {}
        }
        if section == 0 || !t.starts_with('|') {
            continue;
        }
        let cells: Vec<String> = t
            .trim_matches('|')
            .split('|')
            .map(|c| c.trim().to_string())
            .collect();
        if cells.iter().all(|c| c.chars().all(|ch| "-: ".contains(ch))) {
            continue; // separator row
        }
        if section == 1 {
            if cells[0].eq_ignore_ascii_case("rank") || cells.len() < 3 {
                continue;
            }
            let rank = match cells[0].parse::<i64>() {
                Ok(r) => r,
                Err(_) => continue,
            };
            for site in cells[2].replace('`', "").split(',') {
                let site = site.trim();
                if !site.is_empty() {
                    docs.lock_ranks.insert(site.to_string(), rank);
                }
            }
        } else {
            if cells[0].eq_ignore_ascii_case("file") || cells.len() < 5 {
                continue;
            }
            docs.atomics.push(AtomicRow {
                file: cells[0].replace('`', ""),
                field: cells[1].replace('`', ""),
                op: cells[2].replace('`', ""),
                ordering: cells[3].replace('`', ""),
                rationale: cells[4].clone(),
                line: lineno,
            });
        }
    }
    docs
}

fn decode_value(v: &str) -> String {
    if !v.starts_with('"') {
        return v.to_string();
    }
    let inner: Vec<char> = v.chars().collect();
    let mut out = String::new();
    let mut i = 1usize;
    while i < inner.len() {
        let c = inner[i];
        if c == '"' {
            break;
        }
        if c == '\\' && i + 1 < inner.len() {
            match inner[i + 1] {
                'n' => out.push('\n'),
                't' => out.push('\t'),
                e => out.push(e),
            }
            i += 2;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

/// Parse analyze.allow.toml (flat `[[allow]]` entries; no toml crate).
pub fn parse_allowlist(path: &Path) -> Vec<AllowEntry> {
    let mut out: Vec<AllowEntry> = Vec::new();
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => return out,
    };
    for raw in text.split('\n') {
        let t = raw.trim();
        if t == "[[allow]]" {
            out.push(AllowEntry::default());
            continue;
        }
        if t.starts_with('#') || !t.contains('=') {
            continue;
        }
        let cur = match out.last_mut() {
            Some(c) => c,
            None => continue,
        };
        let (key, val) = match t.split_once('=') {
            Some((k, v)) => (k.trim(), decode_value(v.trim())),
            None => continue,
        };
        match key {
            "rule" => cur.rule = val,
            "file" => cur.file = val,
            "pattern" => cur.pattern = val,
            "reason" => cur.reason = val,
            _ => {}
        }
    }
    out
}

fn collect_rs(dir: &Path, root: &Path, out: &mut Vec<String>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, root, out);
        } else if p.extension() == Some(OsStr::new("rs")) {
            let rel = match p.strip_prefix(root) {
                Ok(r) => r,
                Err(_) => continue,
            };
            out.push(rel.to_string_lossy().replace('\\', "/"));
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::new();
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let code = c as u32;
                out.push_str(&format!("\\u{code:04x}"));
            }
            c => out.push(c),
        }
    }
    out
}

fn finding_json(f: &Finding, suppressed: bool) -> String {
    format!(
        "  {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\", \
         \"snippet\": \"{}\", \"suppressed\": {}}}",
        json_escape(f.rule),
        json_escape(&f.file),
        f.line,
        json_escape(&f.message),
        json_escape(&f.snippet),
        suppressed
    )
}

/// Analyze the whole tree. `args` may contain `--dump-atomics` (print the
/// atomics inventory as TSV and exit) and `--json PATH` (write machine-
/// readable findings). Returns the process exit code.
pub fn run(root: &Path, args: &[String]) -> i32 {
    let dump = args.iter().any(|a| a == "--dump-atomics");
    let json_path = args.iter().position(|a| a == "--json").and_then(|p| args.get(p + 1));

    let mut files: Vec<String> = Vec::new();
    for base in ["rust/src", "rust/tests"] {
        collect_rs(&root.join(base), root, &mut files);
    }
    files.sort();

    let docs = parse_docs(&root.join("docs/CONCURRENCY.md"));
    let mut findings: Vec<Finding> = Vec::new();
    type AtomicKey = (String, String, String, String);
    let mut all_atomics: BTreeMap<AtomicKey, Vec<usize>> = BTreeMap::new();
    for rel in &files {
        let src = match fs::read_to_string(root.join(rel)) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let (f, sites) = analyze_file(rel, &src, &docs);
        findings.extend(f);
        for a in sites {
            let key = (rel.clone(), a.field, a.op, a.ordering);
            all_atomics.entry(key).or_default().push(a.line);
        }
    }

    // AL-04 drift, both directions, plus empty rationales.
    let mut table_keys: HashSet<AtomicKey> = HashSet::new();
    for r in &docs.atomics {
        table_keys.insert((r.file.clone(), r.field.clone(), r.op.clone(), r.ordering.clone()));
    }
    for (key, lns) in &all_atomics {
        if !table_keys.contains(key) {
            let (file, field, op, ord) = key;
            findings.push(plain("AL-04", file, lns[0], msg_al04_missing(field, op, ord)));
        }
    }
    for r in &docs.atomics {
        let key = (r.file.clone(), r.field.clone(), r.op.clone(), r.ordering.clone());
        if !all_atomics.contains_key(&key) {
            findings.push(plain("AL-04", "docs/CONCURRENCY.md", r.line, msg_al04_stale(r)));
        }
        if r.rationale.trim().is_empty() {
            let msg = "atomics-table row has an empty rationale".to_string();
            findings.push(plain("AL-04", "docs/CONCURRENCY.md", r.line, msg));
        }
    }

    if dump {
        for ((rel, field, op, ord), lns) in &all_atomics {
            let l: Vec<String> = lns.iter().map(|x| x.to_string()).collect();
            let joined = l.join(",");
            println!("{rel}\t{field}\t{op}\t{ord}\t{joined}");
        }
        return 0;
    }

    // Allowlist: rule + file must match exactly; pattern (if any) must be a
    // substring of the offending source line.
    let mut allow = parse_allowlist(&root.join("analyze.allow.toml"));
    let mut sup_flags: Vec<bool> = vec![false; findings.len()];
    for (fi, f) in findings.iter().enumerate() {
        for a in allow.iter_mut() {
            if a.rule == f.rule
                && a.file == f.file
                && (a.pattern.is_empty() || f.snippet.contains(&a.pattern))
            {
                a.used += 1;
                sup_flags[fi] = true;
                break;
            }
        }
    }
    let mut unsuppressed: Vec<Finding> = Vec::new();
    for (fi, f) in findings.iter().enumerate() {
        if !sup_flags[fi] {
            unsuppressed.push(f.clone());
        }
    }
    for a in &allow {
        if a.reason.trim().is_empty() {
            unsuppressed.push(plain("ALLOWLIST", "analyze.allow.toml", 0, msg_allow_no_reason(a)));
        }
        if a.used == 0 {
            unsuppressed.push(plain("ALLOWLIST", "analyze.allow.toml", 0, msg_allow_unused(a)));
        }
    }

    if let Some(p) = json_path {
        let mut rows: Vec<String> = Vec::new();
        for (fi, f) in findings.iter().enumerate() {
            rows.push(finding_json(f, sup_flags[fi]));
        }
        for f in unsuppressed.iter().filter(|f| f.rule == "ALLOWLIST") {
            rows.push(finding_json(f, false));
        }
        let body = format!("[\n{}\n]\n", rows.join(",\n"));
        if let Err(e) = fs::write(p, body) {
            eprintln!("warning: could not write findings JSON to {p}: {e}");
        }
    }

    let total = findings.len();
    let shown = unsuppressed.len();
    let suppressed = sup_flags.iter().filter(|&&x| x).count();
    println!("{total} findings, {suppressed} suppressed, {shown} unsuppressed");
    for f in &unsuppressed {
        let snip: String = f.snippet.chars().take(80).collect();
        let rule = f.rule;
        let file = f.file.as_str();
        let line = f.line;
        let msg = f.message.as_str();
        println!("  {rule} {file}:{line} {msg}  [{snip}]");
    }
    if unsuppressed.is_empty() {
        0
    } else {
        1
    }
}
