use std::path::PathBuf;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) != Some("analyze") {
        eprintln!("usage: cargo run -p xtask -- analyze [--dump-atomics] [--json PATH]");
        std::process::exit(2);
    }
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
    let root = root.canonicalize().unwrap_or(root);
    std::process::exit(xtask::run(&root, &args[1..]));
}
