//! Fixture tests for each analyzer rule, plus a self-check that the real
//! tree is clean (zero unsuppressed findings with the committed allowlist).

use std::path::Path;
use xtask::{analyze_file, parse_docs, Docs};

fn rules(findings: &[xtask::Finding], rule: &str) -> Vec<String> {
    findings
        .iter()
        .filter(|f| f.rule == rule)
        .map(|f| format!("{}:{} {}", f.file, f.line, f.message))
        .collect()
}

#[test]
fn al01_flags_unsafe_block_without_safety_comment() {
    let src = "pub fn f(v: &[f32]) -> f32 {\n    unsafe { *v.get_unchecked(0) }\n}\n";
    let (f, _) = analyze_file("rust/src/tensor/fix.rs", src, &Docs::default());
    assert_eq!(rules(&f, "AL-01").len(), 1, "{f:?}");
}

#[test]
fn al01_accepts_safety_comment_and_skips_unsafe_impl() {
    let src = "unsafe impl Send for W {}\n\
               pub fn f(v: &[f32]) -> f32 {\n\
                   // SAFETY: caller guarantees v is nonempty.\n\
                   unsafe { *v.get_unchecked(0) }\n\
               }\n";
    let (f, _) = analyze_file("rust/src/tensor/fix.rs", src, &Docs::default());
    assert!(rules(&f, "AL-01").is_empty(), "{f:?}");
}

#[test]
fn al01_comment_run_may_span_multiple_lines() {
    let src = "pub fn f(v: &[f32]) -> f32 {\n\
               // SAFETY: caller guarantees v is nonempty\n\
               // and the index is in range.\n\
               unsafe { *v.get_unchecked(0) }\n\
               }\n";
    let (f, _) = analyze_file("rust/src/tensor/fix.rs", src, &Docs::default());
    assert!(rules(&f, "AL-01").is_empty(), "{f:?}");
}

#[test]
fn al02_flags_panics_in_gated_dirs_only() {
    let src = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let (f, _) = analyze_file("rust/src/serve/fix.rs", src, &Docs::default());
    assert_eq!(rules(&f, "AL-02").len(), 1, "{f:?}");
    let (f, _) = analyze_file("rust/src/util/fix.rs", src, &Docs::default());
    assert!(rules(&f, "AL-02").is_empty(), "util/ is not gated: {f:?}");
}

#[test]
fn al02_ignores_cfg_test_regions_and_comments_and_strings() {
    let src = "pub fn f() -> &'static str {\n\
                   // a comment saying .unwrap() is fine here\n\
                   \"string with panic!(boom) inside\"\n\
               }\n\
               #[cfg(test)]\n\
               mod tests {\n\
                   #[test]\n\
                   fn t() {\n\
                       Some(1).unwrap();\n\
                       panic!(\"test-only\");\n\
                   }\n\
               }\n";
    let (f, _) = analyze_file("rust/src/serve/fix.rs", src, &Docs::default());
    assert!(rules(&f, "AL-02").is_empty(), "{f:?}");
}

#[test]
fn al03_flags_allocations_only_inside_scratch_fns() {
    let src = "pub fn step_scratch(x: &[f32]) -> usize {\n\
                   let v = vec![0.0f32; 4];\n\
                   let w: Vec<f32> = Vec::new();\n\
                   let c = x.to_vec();\n\
                   v.len() + w.len() + c.len()\n\
               }\n\
               pub fn setup(x: &[f32]) -> Vec<f32> {\n\
                   x.to_vec()\n\
               }\n";
    let (f, _) = analyze_file("rust/src/model/fix.rs", src, &Docs::default());
    let hits = rules(&f, "AL-03");
    assert_eq!(hits.len(), 3, "{hits:?}");
    assert!(hits.iter().all(|h| h.contains("step_scratch")), "{hits:?}");
}

#[test]
fn al04_resolves_receiver_op_and_ordering() {
    let src = "pub fn f(c: &S) -> usize {\n\
                   c.hits.fetch_add(1, Ordering::Relaxed);\n\
                   c.ready.load(Ordering::Acquire)\n\
               }\n";
    let (f, at) = analyze_file("rust/src/serve/fix.rs", src, &Docs::default());
    assert!(rules(&f, "AL-04").is_empty(), "{f:?}");
    let got: Vec<String> = at
        .iter()
        .map(|a| format!("{}.{}:{}", a.field, a.op, a.ordering))
        .collect();
    assert_eq!(got, ["hits.fetch_add:Relaxed", "ready.load:Acquire"]);
}

#[test]
fn al04_joins_fetch_update_orderings() {
    let src = "pub fn f(c: &S) {\n\
                   c.n.fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| Some(v + 1));\n\
               }\n";
    let (_, at) = analyze_file("rust/src/serve/fix.rs", src, &Docs::default());
    assert_eq!(at.len(), 1);
    assert_eq!(at[0].ordering, "AcqRel/Acquire");
}

fn docs_with_ranks() -> Docs {
    let mut docs = Docs::default();
    docs.lock_ranks.insert("rust/src/serve/fix.rs:low".to_string(), 10);
    docs.lock_ranks.insert("rust/src/serve/fix.rs:high".to_string(), 20);
    docs
}

#[test]
fn al05_flags_out_of_order_nested_locks() {
    let src = "pub fn f(s: &S) {\n\
                   let g1 = s.high.lock().unwrap();\n\
                   let g2 = s.low.lock().unwrap();\n\
                   drop(g2);\n\
                   drop(g1);\n\
               }\n";
    let (f, _) = analyze_file("rust/src/serve/fix.rs", src, &docs_with_ranks());
    let hits = rules(&f, "AL-05");
    assert_eq!(hits.len(), 1, "{hits:?}");
    assert!(hits[0].contains("rank 10"), "{hits:?}");
}

#[test]
fn al05_accepts_ordered_nesting_and_sequential_locks() {
    let src = "pub fn ordered(s: &S) {\n\
                   let g1 = s.low.lock().unwrap();\n\
                   let g2 = s.high.lock().unwrap();\n\
                   drop(g2);\n\
                   drop(g1);\n\
               }\n\
               pub fn sequential(s: &S) {\n\
                   {\n\
                       let g = s.high.lock().unwrap();\n\
                       drop(g);\n\
                   }\n\
                   let g = s.low.lock().unwrap();\n\
                   drop(g);\n\
               }\n";
    let (f, _) = analyze_file("rust/src/serve/fix.rs", src, &docs_with_ranks());
    assert!(rules(&f, "AL-05").is_empty(), "{f:?}");
}

#[test]
fn al05_flags_undeclared_lock_class() {
    let src = "pub fn f(s: &S) {\n    let g = s.mystery.lock().unwrap();\n    drop(g);\n}\n";
    let (f, _) = analyze_file("rust/src/serve/fix.rs", src, &docs_with_ranks());
    let hits = rules(&f, "AL-05");
    assert_eq!(hits.len(), 1, "{hits:?}");
    assert!(hits[0].contains("not declared"), "{hits:?}");
}

#[test]
fn al06_requires_condvar_waits_in_loops() {
    let bad = "fn f(cv: &Condvar, m: &Mutex<bool>) {\n\
                   let g = m.lock().unwrap();\n\
                   let _g = cv.wait(g).unwrap();\n\
               }\n";
    let (f, _) = analyze_file("rust/tests/fix.rs", bad, &Docs::default());
    assert_eq!(rules(&f, "AL-06").len(), 1, "{f:?}");

    let good = "fn f(cv: &Condvar, m: &Mutex<bool>) {\n\
                    let mut g = m.lock().unwrap();\n\
                    while !*g {\n\
                        g = cv.wait(g).unwrap();\n\
                    }\n\
                }\n";
    let (f, _) = analyze_file("rust/tests/fix.rs", good, &Docs::default());
    assert!(rules(&f, "AL-06").is_empty(), "{f:?}");
}

#[test]
fn al06_ignores_zero_arg_ticket_wait() {
    let src = "fn f(t: &Ticket) {\n    t.wait();\n}\n";
    let (f, _) = analyze_file("rust/tests/fix.rs", src, &Docs::default());
    assert!(rules(&f, "AL-06").is_empty(), "{f:?}");
}

#[test]
fn lexer_does_not_lose_lines_on_string_continuations() {
    let src = "fn f() {}\n\
               fn g() -> String {\n\
               format!(\n\
               \"a \\\n\
               b\",\n\
               )\n\
               }\n\
               fn h(x: Option<u32>) -> u32 {\n\
               x.unwrap()\n\
               }\n";
    let (f, _) = analyze_file("rust/src/serve/fix.rs", src, &Docs::default());
    let hits = rules(&f, "AL-02");
    assert_eq!(hits.len(), 1, "{hits:?}");
    assert!(hits[0].starts_with("rust/src/serve/fix.rs:9 "), "{hits:?}");
}

#[test]
fn concurrency_doc_tables_parse() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let docs = parse_docs(&root.join("docs/CONCURRENCY.md"));
    assert!(
        docs.lock_ranks.contains_key("rust/src/model/kv.rs:state"),
        "lock table missing kv.rs:state: {:?}",
        docs.lock_ranks
    );
    assert!(docs.atomics.len() >= 50, "atomics table too small: {}", docs.atomics.len());
    assert!(docs.atomics.iter().all(|r| !r.rationale.trim().is_empty()));
}

#[test]
fn real_tree_is_clean_under_committed_allowlist() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let code = xtask::run(&root, &[]);
    assert_eq!(code, 0, "analyze found unsuppressed findings; run cargo run -p xtask -- analyze");
}
