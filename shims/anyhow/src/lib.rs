//! Offline shim of the `anyhow` API surface this workspace uses.
//!
//! The build environment has no crates.io access (DESIGN.md §6), so the
//! crates the code depends on are vendored as minimal in-repo shims.
//! This one provides [`Error`], [`Result`], the [`anyhow!`] / [`ensure!`]
//! / [`bail!`] macros, and the [`Context`] extension trait — enough to be
//! source-compatible with the real crate for this codebase, not a general
//! replacement.

use std::fmt;

/// A string-backed error with a context chain.
///
/// `chain[0]` is the outermost (most recently attached) context; the last
/// element is the root cause.  `{e}` prints the outermost message only,
/// `{e:#}` prints the whole chain separated by `: ` — matching how the
/// real `anyhow::Error` formats.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Preserve the source chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Wrap the error with an outer context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// A dedicated impl for results already carrying an [`Error`] keeps the
// existing context chain instead of flattening it to the top message
// (matches the real crate; `Error` does not implement `std::error::Error`,
// so this cannot overlap the impl above).
impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!(
                concat!("condition failed: `", stringify!($cond), "`")
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("root").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        let o: Option<u32> = None;
        assert!(o.with_context(|| "empty").is_err());
    }

    #[test]
    fn context_on_anyhow_result_preserves_chain() {
        let inner: Result<()> = Err(Error::msg("root").context("mid"));
        let e = inner.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        assert_eq!(format!("{e}"), "outer");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{}", f(12).unwrap_err()).contains("12"));
        assert!(format!("{}", f(7).unwrap_err()).contains("unlucky"));
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain");
        let b = anyhow!("value {}", 3);
        let c = anyhow!(String::from("owned"));
        assert_eq!(format!("{a}"), "plain");
        assert_eq!(format!("{b}"), "value 3");
        assert_eq!(format!("{c}"), "owned");
    }
}
