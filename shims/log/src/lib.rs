//! Offline shim of the `log` facade.
//!
//! Provides the five logging macros plus level filtering, with a built-in
//! stderr backend (timestamped with seconds since first log).  The
//! `permllm::util::logging::init` wrapper selects the level from the
//! `PERMLLM_LOG` env var.  Source-compatible with the call sites in this
//! workspace, not a general replacement for the real crate.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Severity of a log record (most to least severe).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Maximum level that will be emitted (`Off` silences everything).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Info as usize);
static START: OnceLock<Instant> = OnceLock::new();

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Whether a record at `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level as usize <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Backend for the macros: filter, then print one line to stderr.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {target}] {args}", level.label());
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test so the global level is not mutated concurrently.
    #[test]
    fn filtering_and_macros() {
        set_max_level(LevelFilter::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(LevelFilter::Trace);
        assert!(enabled(Level::Trace));
        set_max_level(LevelFilter::Off);
        assert!(!enabled(Level::Error));

        set_max_level(LevelFilter::Info);
        let x = 41;
        info!("answer-ish {x}");
        warn!("{} {}", "two", "args");
        error!("plain");
        debug!("filtered out by default {x}");
        trace!("also filtered {x}");
    }
}
