//! Typed offline stub of the `xla` PJRT bindings.
//!
//! The real `xla` crate links the PJRT C API (xla_extension) and cannot be
//! fetched or built in the offline environment, so the `pjrt` cargo
//! feature of `permllm` compiles against this stub instead: host-side
//! [`Literal`] operations work for real (they are plain buffers), while
//! anything that would need the PJRT runtime — building a client, parsing
//! HLO, compiling, executing — returns a clear [`XlaError`] at *runtime*.
//!
//! This keeps `--features pjrt` type-checking (and its call sites honest)
//! everywhere, and lets an environment that has the real xla_extension
//! swap this path dependency for the genuine crate with no source change.

use std::fmt;

/// Error type mirroring the real crate's (Display + std::error::Error).
#[derive(Debug)]
pub struct XlaError(String);

impl XlaError {
    fn stub(what: &str) -> XlaError {
        XlaError(format!(
            "xla stub: {what} requires the real PJRT runtime (xla_extension); \
             replace the `shims/xla` path dependency with the real `xla` crate"
        ))
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

/// Element types a [`Literal`] can hold (public because it appears in
/// the [`NativeType`] conversion signatures; not part of the real API).
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Buf {
    fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
        }
    }
}

/// Sealed-ish conversion trait for supported element types.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Buf;
    fn unwrap(buf: &Buf) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Buf {
        Buf::F32(data)
    }

    fn unwrap(buf: &Buf) -> Option<Vec<f32>> {
        match buf {
            Buf::F32(v) => Some(v.clone()),
            Buf::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Buf {
        Buf::I32(data)
    }

    fn unwrap(buf: &Buf) -> Option<Vec<i32>> {
        match buf {
            Buf::I32(v) => Some(v.clone()),
            Buf::F32(_) => None,
        }
    }
}

/// A host tensor: flat buffer + dims.  Fully functional in the stub.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    buf: Buf,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], buf: T::wrap(data.to_vec()) }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.buf.len() {
            return Err(XlaError(format!(
                "reshape: {} elements into dims {dims:?}",
                self.buf.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), buf: self.buf.clone() })
    }

    pub fn element_count(&self) -> usize {
        self.buf.len()
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.buf).ok_or_else(|| XlaError("to_vec: element type mismatch".into()))
    }

    /// Decompose a tuple literal.  Stub literals are never tuples (they
    /// would come out of an execution, which the stub cannot perform).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError::stub("decomposing an execution result tuple"))
    }
}

/// Parsed HLO module handle (opaque; unconstructible in the stub).
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError::stub("parsing HLO text"))
    }
}

/// Computation wrapper.
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::stub("fetching a device buffer"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Generic over the element so callers can pass owned literals
    /// (`&[Literal]`) or borrowed ones (`&[&Literal]`) — the engine's
    /// resident-weight path executes bound statics by reference, and a
    /// real-crate swap must keep that zero-copy call shape.
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::stub("executing a computation"))
    }
}

/// PJRT client handle.  [`PjRtClient::cpu`] fails in the stub, so no
/// client (and nothing downstream of one) can ever exist.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::stub("creating a CPU PJRT client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::stub("compiling a computation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn i32_literals_work() {
        let l = Literal::vec1(&[7i32, 8]).reshape(&[2, 1]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn runtime_entry_points_fail_with_guidance() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
